//! Baseline tokenizers for Fig 4 — same vocabulary, same greedy
//! lowest-rank BPE, different data structures.
//!
//! The paper compares against HuggingFace's tokenizer (used by vLLM and
//! SGLang) and llama.cpp's. Neither is available offline, so we build
//! stand-ins that reproduce each design's *data-structure class* (the
//! property Fig 4 actually measures — see DESIGN.md §2):
//!
//! * [`NaiveTokenizer`]: SipHash `std::collections::HashMap` for merges,
//!   heap-allocated symbol nodes behind pointers, fresh buffers per call —
//!   the allocation-and-indirection profile of a Python/Rust-binding
//!   tokenizer.
//! * [`HeapliteTokenizer`]: llama.cpp's shape — a bigram `BinaryHeap`
//!   keyed by merge rank with lazy invalidation, std HashMap lookups.

use super::{pretokenize, Piece, Tokenizer, Vocab};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// HF stand-in: pointer-chasing node list + SipHash map + per-call allocs.
pub struct NaiveTokenizer {
    merges: HashMap<(u32, u32), (u32, u32)>,
}

#[allow(clippy::vec_box)] // the boxing *is* the point: pointer-chasing baseline
struct NaiveNode {
    sym: u32,
    alive: bool,
}

impl NaiveTokenizer {
    pub fn new(vocab: &Vocab) -> NaiveTokenizer {
        let merges = vocab
            .merges
            .iter()
            .enumerate()
            .map(|(rank, &(a, b, n))| ((a, b), (n, rank as u32)))
            .collect();
        NaiveTokenizer { merges }
    }

    fn encode_word(&self, word: &[u8], attach_space: bool, out: &mut Vec<u32>) {
        // Fresh heap allocations per word, nodes behind Box.
        let mut nodes: Vec<Box<NaiveNode>> = Vec::new();
        if attach_space {
            nodes.push(Box::new(NaiveNode { sym: b' ' as u32, alive: true }));
        }
        for &b in word {
            nodes.push(Box::new(NaiveNode { sym: b as u32, alive: true }));
        }
        loop {
            let mut best: Option<(u32, usize, usize, u32)> = None; // rank, i, j, new
            let live: Vec<usize> =
                (0..nodes.len()).filter(|&i| nodes[i].alive).collect();
            for w in live.windows(2) {
                let (i, j) = (w[0], w[1]);
                if let Some(&(new_id, rank)) = self.merges.get(&(nodes[i].sym, nodes[j].sym)) {
                    if best.is_none_or(|(r, ..)| rank < r) {
                        best = Some((rank, i, j, new_id));
                    }
                }
            }
            match best {
                Some((_, i, j, new_id)) => {
                    nodes[i].sym = new_id;
                    nodes[j].alive = false;
                }
                None => break,
            }
        }
        out.extend(nodes.iter().filter(|n| n.alive).map(|n| n.sym));
    }
}

impl Tokenizer for NaiveTokenizer {
    fn encode(&self, text: &str, out: &mut Vec<u32>) {
        pretokenize(text.as_bytes(), |p| match p {
            Piece::Ws(b) => out.push(b as u32),
            Piece::Word(w, sp) => self.encode_word(w, sp, out),
        });
    }

    fn name(&self) -> &'static str {
        "naive-hf"
    }
}

/// llama.cpp stand-in: bigram priority queue with lazy invalidation.
pub struct HeapliteTokenizer {
    merges: HashMap<(u32, u32), (u32, u32)>,
}

#[derive(PartialEq, Eq)]
struct Bigram {
    rank: u32,
    left: usize,
    new_id: u32,
    /// Snapshot of the pair for lazy invalidation after merges.
    pair: (u32, u32),
}

impl Ord for Bigram {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Min-heap by (rank, position) via Reverse at push sites.
        (self.rank, self.left).cmp(&(other.rank, other.left))
    }
}

impl PartialOrd for Bigram {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl HeapliteTokenizer {
    pub fn new(vocab: &Vocab) -> HeapliteTokenizer {
        let merges = vocab
            .merges
            .iter()
            .enumerate()
            .map(|(rank, &(a, b, n))| ((a, b), (n, rank as u32)))
            .collect();
        HeapliteTokenizer { merges }
    }

    fn encode_word(&self, word: &[u8], attach_space: bool, out: &mut Vec<u32>) {
        let mut syms: Vec<u32> = Vec::with_capacity(word.len() + 1);
        if attach_space {
            syms.push(b' ' as u32);
        }
        syms.extend(word.iter().map(|&b| b as u32));
        let n = syms.len();
        if n == 0 {
            return;
        }
        let mut next: Vec<i32> = (0..n).map(|i| if i + 1 < n { i as i32 + 1 } else { -1 }).collect();
        let mut prev: Vec<i32> = (0..n).map(|i| i as i32 - 1).collect();
        let mut heap: BinaryHeap<Reverse<Bigram>> = BinaryHeap::new();
        let push = |heap: &mut BinaryHeap<Reverse<Bigram>>, syms: &[u32], i: usize, j: usize, merges: &HashMap<(u32, u32), (u32, u32)>| {
            if let Some(&(new_id, rank)) = merges.get(&(syms[i], syms[j])) {
                heap.push(Reverse(Bigram { rank, left: i, new_id, pair: (syms[i], syms[j]) }));
            }
        };
        for i in 0..n.saturating_sub(1) {
            push(&mut heap, &syms, i, i + 1, &self.merges);
        }
        while let Some(Reverse(bg)) = heap.pop() {
            let i = bg.left;
            let j = next[i];
            // Lazy invalidation: stale if the pair changed under us.
            if j < 0 || (syms[i], syms[j as usize]) != bg.pair {
                continue;
            }
            let j = j as usize;
            syms[i] = bg.new_id;
            let jj = next[j];
            next[i] = jj;
            if jj >= 0 {
                prev[jj as usize] = i as i32;
            }
            // Mark j dead by clearing its links.
            next[j] = -2;
            if prev[i] >= 0 {
                push(&mut heap, &syms, prev[i] as usize, i, &self.merges);
            }
            if jj >= 0 {
                push(&mut heap, &syms, i, jj as usize, &self.merges);
            }
        }
        let mut i = 0i32;
        while i >= 0 {
            out.push(syms[i as usize]);
            i = next[i as usize];
        }
    }
}

impl Tokenizer for HeapliteTokenizer {
    fn encode(&self, text: &str, out: &mut Vec<u32>) {
        pretokenize(text.as_bytes(), |p| match p {
            Piece::Ws(b) => out.push(b as u32),
            Piece::Word(w, sp) => self.encode_word(w, sp, out),
        });
    }

    fn name(&self) -> &'static str {
        "heaplite-llamacpp"
    }
}

#[cfg(test)]
mod tests {
    use super::super::blink::BlinkTokenizer;
    use super::super::tests::tiny_vocab;
    use super::*;
    use crate::util::prop::run_prop;

    #[test]
    fn all_three_agree_on_simple_text() {
        let v = tiny_vocab();
        let blink = BlinkTokenizer::new(&v);
        let naive = NaiveTokenizer::new(&v);
        let heap = HeapliteTokenizer::new(&v);
        for text in ["the the", " the", "x y z", "", "the\n\nthe", "  double"] {
            let (mut a, mut b, mut c) = (vec![], vec![], vec![]);
            blink.encode(text, &mut a);
            naive.encode(text, &mut b);
            heap.encode(text, &mut c);
            assert_eq!(a, b, "blink vs naive on {text:?}");
            assert_eq!(a, c, "blink vs heaplite on {text:?}");
        }
    }

    #[test]
    fn prop_agreement_on_random_ascii() {
        let v = tiny_vocab();
        let blink = BlinkTokenizer::new(&v);
        let naive = NaiveTokenizer::new(&v);
        let heap = HeapliteTokenizer::new(&v);
        run_prop("tokenizer-agreement", 0x70C1, 200, |rng| {
            let len = rng.below(60) as usize;
            let text: String = (0..len)
                .map(|_| {
                    let c = rng.below(6);
                    match c {
                        0 => ' ',
                        1 => 't',
                        2 => 'h',
                        3 => 'e',
                        4 => '\n',
                        _ => (b'a' + rng.below(26) as u8) as char,
                    }
                })
                .collect();
            let (mut a, mut b, mut c) = (vec![], vec![], vec![]);
            blink.encode(&text, &mut a);
            naive.encode(&text, &mut b);
            heap.encode(&text, &mut c);
            assert_eq!(a, b, "text {text:?}");
            assert_eq!(a, c, "text {text:?}");
            // And the roundtrip is lossless.
            assert_eq!(super::super::decode(&v, &a), text);
        });
    }
}
