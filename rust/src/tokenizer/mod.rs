//! Tokenization on the DPU (paper §4.4, Fig 4).
//!
//! Three byte-level BPE implementations share one trained vocabulary
//! (``artifacts/vocab.blink``, built by python/compile/tokenizer_train.py)
//! and one greedy lowest-rank merge algorithm, differing only in data
//! structures — the axis Fig 4 measures:
//!
//! * [`blink::BlinkTokenizer`] — the paper's design: merge rules in a
//!   64-byte-aligned flat hash table packing four key-value pairs per L1D
//!   cache line, SWAR byte classification for pre-tokenization (the NEON
//!   analogue), and pre-allocated thread-local buffers so the request
//!   path never heap-allocates.
//! * [`baselines::NaiveTokenizer`] — the HuggingFace stand-in: SipHash
//!   std HashMap, per-node heap allocation, fresh buffers per request.
//! * [`baselines::HeapliteTokenizer`] — the llama.cpp stand-in: bigram
//!   priority queue (BinaryHeap) + std HashMap merge lookup.
//!
//! All three must produce *identical* token streams (asserted by tests
//! and property sweeps); only their latency differs.

pub mod baselines;
pub mod blink;

use std::path::Path;

/// The trained vocabulary: ids 0..256 are raw bytes; merged tokens follow.
#[derive(Debug, Clone)]
pub struct Vocab {
    /// id -> byte string.
    pub tokens: Vec<Vec<u8>>,
    /// (left, right, new_id); index in this list is the merge rank.
    pub merges: Vec<(u32, u32, u32)>,
}

impl Vocab {
    pub fn load(path: &Path) -> Result<Vocab, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Vocab, String> {
        let mut lines = text.lines();
        let header = lines.next().ok_or("empty vocab file")?;
        if header != "blink-vocab v1" {
            return Err(format!("bad vocab header: {header}"));
        }
        let mut vocab_size = 0usize;
        let mut tokens: Vec<Vec<u8>> = Vec::new();
        let mut merges = Vec::new();
        for line in lines {
            let mut it = line.split_whitespace();
            match it.next() {
                Some("vocab_size") => {
                    vocab_size = it.next().and_then(|s| s.parse().ok()).ok_or("bad vocab_size")?;
                    tokens = vec![Vec::new(); vocab_size];
                }
                Some("merges") => {}
                Some("TOKEN") => {
                    let id: usize =
                        it.next().and_then(|s| s.parse().ok()).ok_or("bad TOKEN id")?;
                    let hex = it.next().ok_or("bad TOKEN hex")?;
                    if id >= tokens.len() {
                        return Err(format!("TOKEN id {id} out of range"));
                    }
                    tokens[id] = hex_decode(hex)?;
                }
                Some("MERGE") => {
                    let a: u32 = it.next().and_then(|s| s.parse().ok()).ok_or("bad MERGE")?;
                    let b: u32 = it.next().and_then(|s| s.parse().ok()).ok_or("bad MERGE")?;
                    let n: u32 = it.next().and_then(|s| s.parse().ok()).ok_or("bad MERGE")?;
                    merges.push((a, b, n));
                }
                _ => {}
            }
        }
        if tokens.len() != vocab_size || tokens.iter().take(256).any(|t| t.len() != 1) {
            return Err("malformed vocab".into());
        }
        Ok(Vocab { tokens, merges })
    }

    pub fn size(&self) -> usize {
        self.tokens.len()
    }
}

fn hex_decode(s: &str) -> Result<Vec<u8>, String> {
    if s.len() % 2 != 0 {
        return Err("odd hex".into());
    }
    (0..s.len() / 2)
        .map(|i| u8::from_str_radix(&s[2 * i..2 * i + 2], 16).map_err(|e| e.to_string()))
        .collect()
}

/// Common interface: encode appends ids to `out` (no allocation mandated);
/// all implementations are `Sync` so DPU worker threads share one instance.
pub trait Tokenizer: Send + Sync {
    fn encode(&self, text: &str, out: &mut Vec<u32>);
    fn name(&self) -> &'static str;
}

/// A pre-tokenized piece: a raw whitespace byte, or a word (with a flag
/// for whether a single leading space attaches to it).
#[derive(Debug, PartialEq, Eq)]
pub enum Piece<'a> {
    Ws(u8),
    Word(&'a [u8], bool),
}

/// Shared pre-tokenization used by all three implementations, guaranteeing
/// identical segmentation: each word is encoded with its single preceding
/// space attached (the trainer's leading-space convention); any *other*
/// whitespace byte is emitted as a raw byte token, which makes
/// encode→decode lossless for arbitrary text.
pub fn pretokenize<'a>(text: &'a [u8], mut emit: impl FnMut(Piece<'a>)) {
    let mut i = 0;
    let n = text.len();
    while i < n {
        if is_ws(text[i]) {
            // Find the end of the whitespace run (SWAR-accelerated in the
            // blink path; scalar here keeps the shared code simple).
            let start = i;
            while i < n && is_ws(text[i]) {
                i += 1;
            }
            let ws = &text[start..i];
            if i < n && *ws.last().unwrap() == b' ' {
                // Last space attaches to the following word.
                for &b in &ws[..ws.len() - 1] {
                    emit(Piece::Ws(b));
                }
                let wstart = i;
                while i < n && !is_ws(text[i]) {
                    i += 1;
                }
                emit(Piece::Word(&text[wstart..i], true));
            } else {
                for &b in ws {
                    emit(Piece::Ws(b));
                }
            }
        } else {
            let wstart = i;
            while i < n && !is_ws(text[i]) {
                i += 1;
            }
            emit(Piece::Word(&text[wstart..i], false));
        }
    }
}

#[inline]
pub fn is_ws(b: u8) -> bool {
    matches!(b, b' ' | b'\t' | b'\n' | b'\r')
}

/// Streaming detokenizer: accumulates token bytes and flushes the maximal
/// valid UTF-8 prefix (SSE streams strings; tokens may split code points).
#[derive(Default)]
pub struct Detokenizer {
    buf: Vec<u8>,
}

impl Detokenizer {
    pub fn new() -> Detokenizer {
        Detokenizer { buf: Vec::with_capacity(64) }
    }

    pub fn push(&mut self, vocab: &Vocab, token: u32) -> String {
        if let Some(bytes) = vocab.tokens.get(token as usize) {
            self.buf.extend_from_slice(bytes);
        }
        self.flush_valid()
    }

    fn flush_valid(&mut self) -> String {
        match std::str::from_utf8(&self.buf) {
            Ok(s) => {
                let out = s.to_string();
                self.buf.clear();
                out
            }
            Err(e) => {
                let valid = e.valid_up_to();
                let out = String::from_utf8_lossy(&self.buf[..valid]).into_owned();
                self.buf.drain(..valid);
                out
            }
        }
    }

    /// End of stream: emit whatever remains (lossy if truncated mid-char).
    pub fn finish(&mut self) -> String {
        let out = String::from_utf8_lossy(&self.buf).into_owned();
        self.buf.clear();
        out
    }
}

/// Decode a whole token sequence (non-streaming helper).
pub fn decode(vocab: &Vocab, tokens: &[u32]) -> String {
    let mut bytes = Vec::new();
    for &t in tokens {
        if let Some(b) = vocab.tokens.get(t as usize) {
            bytes.extend_from_slice(b);
        }
    }
    String::from_utf8_lossy(&bytes).into_owned()
}

// pub(crate): other modules' unit tests borrow `tiny_vocab` (e.g. the
// frontend session tests); the module only exists under cfg(test).
#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    pub(crate) fn tiny_vocab() -> Vocab {
        // bytes 0..256 + merges building " th", " the"
        let mut tokens: Vec<Vec<u8>> = (0..=255u8).map(|b| vec![b]).collect();
        let mut merges = vec![];
        // id 256 = " t"
        tokens.push(vec![b' ', b't']);
        merges.push((b' ' as u32, b't' as u32, 256));
        // id 257 = " th"
        tokens.push(vec![b' ', b't', b'h']);
        merges.push((256, b'h' as u32, 257));
        // id 258 = " the"
        tokens.push(vec![b' ', b't', b'h', b'e']);
        merges.push((257, b'e' as u32, 258));
        Vocab { tokens, merges }
    }

    #[test]
    fn parse_roundtrip() {
        let v = tiny_vocab();
        let mut text = String::from("blink-vocab v1\n");
        text.push_str(&format!("vocab_size {}\n", v.tokens.len()));
        text.push_str(&format!("merges {}\n", v.merges.len()));
        for (i, t) in v.tokens.iter().enumerate() {
            text.push_str(&format!(
                "TOKEN {i} {}\n",
                t.iter().map(|b| format!("{b:02x}")).collect::<String>()
            ));
        }
        for (r, (a, b, n)) in v.merges.iter().enumerate() {
            text.push_str(&format!("MERGE {a} {b} {n} {r}\n"));
        }
        let parsed = Vocab::parse(&text).unwrap();
        assert_eq!(parsed.tokens, v.tokens);
        assert_eq!(parsed.merges, v.merges);
    }

    #[test]
    fn pretokenize_lossless_segmentation() {
        let text = b"ab  cd\ne f";
        let mut pieces: Vec<(Vec<u8>, bool)> = vec![];
        let mut ws: Vec<u8> = vec![];
        pretokenize(text, |p| match p {
            Piece::Ws(b) => ws.push(b),
            Piece::Word(w, sp) => pieces.push((w.to_vec(), sp)),
        });
        // "ab", one raw space, " cd" (space attached), newline raw, "e", " f"
        assert_eq!(ws, vec![b' ', b'\n']);
        assert_eq!(
            pieces,
            vec![
                (b"ab".to_vec(), false),
                (b"cd".to_vec(), true),
                (b"e".to_vec(), false),
                (b"f".to_vec(), true)
            ]
        );
    }

    #[test]
    fn detokenizer_streams_utf8_safely() {
        let v = tiny_vocab();
        // 'é' = bytes 0xC3 0xA9: byte-level ids are the bytes themselves.
        let mut d = Detokenizer::new();
        assert_eq!(d.push(&v, 0xC3), "");
        assert_eq!(d.push(&v, 0xA9), "é");
        assert_eq!(d.finish(), "");
    }

    #[test]
    fn rejects_bad_header() {
        assert!(Vocab::parse("nope v9\n").is_err());
    }
}
