//! Blink's tokenizer (paper §4.4 "Tokenizer"):
//!
//! * merge rules in a **64-byte-aligned flat hash table** packing four
//!   key-value pairs per L1D cache line (open addressing, bucket-linear
//!   probing) — one cache line per probe step instead of SipHash + bucket
//!   pointer chasing;
//! * **SWAR byte classification** for pre-tokenization, the portable
//!   analogue of the BlueField A78 NEON path (classifies 8 bytes per
//!   step with branch-free zero-byte tricks);
//! * **pre-allocated thread-local buffers** for all per-request state —
//!   zero heap allocation on the request path.

use super::{pretokenize, Tokenizer, Vocab};
use std::cell::RefCell;

const EMPTY_KEY: u64 = u64::MAX;

/// One cache line: 4 keys + 4 values = 64 bytes.
#[repr(C, align(64))]
#[derive(Clone, Copy)]
struct Bucket {
    keys: [u64; 4],
    vals: [u64; 4],
}

impl Bucket {
    const fn empty() -> Bucket {
        Bucket { keys: [EMPTY_KEY; 4], vals: [0; 4] }
    }
}

/// Flat hash table over merge pairs: key = (left<<32)|right, value =
/// (new_id<<32)|rank.
pub struct FlatMergeTable {
    buckets: Vec<Bucket>,
    mask: usize,
    pub entries: usize,
}

impl FlatMergeTable {
    pub fn build(merges: &[(u32, u32, u32)]) -> FlatMergeTable {
        // Load factor <= 0.5 over entries; buckets hold 4 entries each.
        let min_buckets = (merges.len() * 2).div_ceil(4).max(4);
        let nbuckets = min_buckets.next_power_of_two();
        let mut t = FlatMergeTable {
            buckets: vec![Bucket::empty(); nbuckets],
            mask: nbuckets - 1,
            entries: 0,
        };
        for (rank, &(a, b, n)) in merges.iter().enumerate() {
            t.insert(pair_key(a, b), ((n as u64) << 32) | rank as u64);
        }
        t
    }

    #[inline]
    fn hash(key: u64) -> u64 {
        // splitmix-style finalizer — 2 multiplies, good avalanche.
        let mut z = key.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        z ^= z >> 29;
        z = z.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z ^ (z >> 32)
    }

    fn insert(&mut self, key: u64, val: u64) {
        let mut b = (Self::hash(key) as usize) & self.mask;
        loop {
            let bucket = &mut self.buckets[b];
            for i in 0..4 {
                if bucket.keys[i] == EMPTY_KEY {
                    bucket.keys[i] = key;
                    bucket.vals[i] = val;
                    self.entries += 1;
                    return;
                }
            }
            b = (b + 1) & self.mask;
        }
    }

    /// Lookup (new_id, rank) for an adjacent pair. The hot path: one hash,
    /// then whole-cache-line scans.
    #[inline]
    pub fn get(&self, left: u32, right: u32) -> Option<(u32, u32)> {
        let key = pair_key(left, right);
        let mut b = (Self::hash(key) as usize) & self.mask;
        loop {
            let bucket = &self.buckets[b];
            for i in 0..4 {
                let k = bucket.keys[i];
                if k == key {
                    let v = bucket.vals[i];
                    return Some(((v >> 32) as u32, v as u32));
                }
                if k == EMPTY_KEY {
                    return None;
                }
            }
            b = (b + 1) & self.mask;
        }
    }

    pub fn table_bytes(&self) -> usize {
        self.buckets.len() * std::mem::size_of::<Bucket>()
    }
}

#[inline]
fn pair_key(a: u32, b: u32) -> u64 {
    ((a as u64) << 32) | b as u64
}

// --- SWAR whitespace classification ---------------------------------------
// Branch-free detection of {' ', '\t', '\n', '\r'} 8 bytes at a time: for
// each candidate byte c, `x ^ splat(c)` has a zero byte exactly where the
// input equals c. Zero bytes are detected with the *carry-free exact*
// formulation `~(((v & 0x7f..) + 0x7f..) | v | 0x7f..)` — the cheaper
// `(v - 0x01..) & ~v & 0x80..` variant has false positives above a true
// zero byte (borrow propagation), which would corrupt `find_nonws`.
// OR the four masks and scan with trailing_zeros.

const LO: u64 = 0x0101_0101_0101_0101;
const HI: u64 = 0x8080_8080_8080_8080;

#[inline]
fn zero_bytes(v: u64) -> u64 {
    !(((v & !HI).wrapping_add(!HI)) | v | !HI) & HI
}

#[inline]
fn ws_mask8(chunk: u64) -> u64 {
    zero_bytes(chunk ^ (LO * b' ' as u64))
        | zero_bytes(chunk ^ (LO * b'\t' as u64))
        | zero_bytes(chunk ^ (LO * b'\n' as u64))
        | zero_bytes(chunk ^ (LO * b'\r' as u64))
}

/// Index of the first whitespace byte at or after `i` (SWAR main loop).
pub fn find_ws(text: &[u8], mut i: usize) -> usize {
    while i + 8 <= text.len() {
        let chunk = u64::from_le_bytes(text[i..i + 8].try_into().unwrap());
        let m = ws_mask8(chunk);
        if m != 0 {
            return i + (m.trailing_zeros() / 8) as usize;
        }
        i += 8;
    }
    while i < text.len() && !super::is_ws(text[i]) {
        i += 1;
    }
    i
}

/// Index of the first non-whitespace byte at or after `i`.
pub fn find_nonws(text: &[u8], mut i: usize) -> usize {
    while i + 8 <= text.len() {
        let chunk = u64::from_le_bytes(text[i..i + 8].try_into().unwrap());
        let m = !ws_mask8(chunk) & HI;
        if m != 0 {
            return i + (m.trailing_zeros() / 8) as usize;
        }
        i += 8;
    }
    while i < text.len() && super::is_ws(text[i]) {
        i += 1;
    }
    i
}

// --- thread-local per-request state ----------------------------------------

struct Scratch {
    /// Symbol ids of the current word (with attached leading space).
    syms: Vec<u32>,
    /// Linked-list next/prev indices for in-place merging.
    next: Vec<i32>,
    prev: Vec<i32>,
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch {
        syms: Vec::with_capacity(4096),
        next: Vec::with_capacity(4096),
        prev: Vec::with_capacity(4096),
    });
}

pub struct BlinkTokenizer {
    table: FlatMergeTable,
}

impl BlinkTokenizer {
    pub fn new(vocab: &Vocab) -> BlinkTokenizer {
        BlinkTokenizer { table: FlatMergeTable::build(&vocab.merges) }
    }

    pub fn table(&self) -> &FlatMergeTable {
        &self.table
    }

    /// Greedy lowest-rank BPE over one word, in the thread-local arena.
    fn encode_word(&self, word: &[u8], attach_space: bool, out: &mut Vec<u32>) {
        SCRATCH.with(|s| {
            let s = &mut *s.borrow_mut();
            s.syms.clear();
            s.next.clear();
            s.prev.clear();
            if attach_space {
                s.syms.push(b' ' as u32);
            }
            s.syms.extend(word.iter().map(|&b| b as u32));
            let n = s.syms.len();
            if n == 0 {
                return;
            }
            for i in 0..n {
                s.next.push(if i + 1 < n { i as i32 + 1 } else { -1 });
                s.prev.push(i as i32 - 1);
            }
            loop {
                // Scan the live list for the lowest-rank adjacent pair.
                let mut best_rank = u32::MAX;
                let mut best_i = -1i32;
                let mut best_new = 0u32;
                let mut i = 0i32;
                while i >= 0 {
                    let j = s.next[i as usize];
                    if j < 0 {
                        break;
                    }
                    if let Some((new_id, rank)) =
                        self.table.get(s.syms[i as usize], s.syms[j as usize])
                    {
                        if rank < best_rank {
                            best_rank = rank;
                            best_i = i;
                            best_new = new_id;
                        }
                    }
                    i = j;
                }
                if best_i < 0 {
                    break;
                }
                // Merge (best_i, next[best_i]) -> best_new in place.
                let i = best_i as usize;
                let j = s.next[i] as usize;
                s.syms[i] = best_new;
                let jj = s.next[j];
                s.next[i] = jj;
                if jj >= 0 {
                    s.prev[jj as usize] = i as i32;
                }
            }
            let mut i = 0i32;
            while i >= 0 {
                out.push(s.syms[i as usize]);
                i = s.next[i as usize];
            }
        });
    }
}

impl Tokenizer for BlinkTokenizer {
    fn encode(&self, text: &str, out: &mut Vec<u32>) {
        // SWAR-driven pre-tokenization loop (same segmentation contract as
        // `super::pretokenize`, asserted by property tests).
        let bytes = text.as_bytes();
        let n = bytes.len();
        let mut i = 0;
        while i < n {
            if super::is_ws(bytes[i]) {
                let end = find_nonws(bytes, i);
                if end < n && bytes[end - 1] == b' ' {
                    for &b in &bytes[i..end - 1] {
                        out.push(b as u32);
                    }
                    let wend = find_ws(bytes, end);
                    self.encode_word(&bytes[end..wend], true, out);
                    i = wend;
                } else {
                    for &b in &bytes[i..end] {
                        out.push(b as u32);
                    }
                    i = end;
                }
            } else {
                let wend = find_ws(bytes, i);
                self.encode_word(&bytes[i..wend], false, out);
                i = wend;
            }
        }
    }

    fn name(&self) -> &'static str {
        "blink"
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::tiny_vocab;
    use super::*;

    #[test]
    fn flat_table_finds_all_merges() {
        let v = tiny_vocab();
        let t = FlatMergeTable::build(&v.merges);
        assert_eq!(t.get(b' ' as u32, b't' as u32), Some((256, 0)));
        assert_eq!(t.get(256, b'h' as u32), Some((257, 1)));
        assert_eq!(t.get(257, b'e' as u32), Some((258, 2)));
        assert_eq!(t.get(1, 2), None);
    }

    #[test]
    fn bucket_is_one_cache_line() {
        assert_eq!(std::mem::size_of::<Bucket>(), 64);
        assert_eq!(std::mem::align_of::<Bucket>(), 64);
    }

    #[test]
    fn swar_finds_boundaries() {
        let text = b"hello world\tand more__________padding";
        assert_eq!(find_ws(text, 0), 5);
        assert_eq!(find_nonws(text, 5), 6);
        assert_eq!(find_ws(text, 6), 11);
        assert_eq!(find_nonws(text, 11), 12);
        // no whitespace until end
        assert_eq!(find_ws(text, 21), text.len());
    }

    #[test]
    fn swar_matches_scalar_on_all_bytes() {
        for b in 0u8..=255 {
            let arr = [b; 8];
            let m = ws_mask8(u64::from_le_bytes(arr));
            let expect = super::super::is_ws(b);
            assert_eq!(m != 0, expect, "byte {b:#x}");
        }
    }

    #[test]
    fn encode_applies_merges_in_rank_order() {
        let v = tiny_vocab();
        let t = BlinkTokenizer::new(&v);
        let mut out = vec![];
        t.encode("x the", &mut out);
        // "x" -> [120]; " the" -> [258]
        assert_eq!(out, vec![b'x' as u32, 258]);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let v = tiny_vocab();
        let t = BlinkTokenizer::new(&v);
        let text = "the theme  thesis\n\tthe end";
        let mut out = vec![];
        t.encode(text, &mut out);
        assert_eq!(super::super::decode(&v, &out), text);
    }
}
