//! Minimal HTTP/1.1 server with an OpenAI-compatible completions endpoint
//! and Server-Sent-Events streaming (paper §4.1 goal (5): drop-in API
//! compatibility). Hand-rolled on std::net — the request path stays inside
//! the DPU plane (frontend threads), no host-side framework.
//!
//! Endpoints:
//! * `POST /v1/completions` — body: `{"prompt": "...", "max_tokens": N,
//!   "stream": true|false}`. Streaming responses use SSE `data:` frames
//!   with OpenAI-style chunk objects, terminated by `data: [DONE]`.
//!   Scheduling extensions (all optional, threaded to the scheduler's
//!   admission policy): `"priority"`: 0–7 (higher = more important, or
//!   `"class": "interactive"|"batch"` as a shorthand) and
//!   `"ttft_deadline_ms"`: a TTFT budget enforced by the SLO-aware
//!   policy and reported per class by the eval. Multi-turn extension:
//!   `"session_id"`: an opaque string naming the conversation — the DPU
//!   frontend prepends the session's tokenized history (prompt carries
//!   only the *new* turn) and the scheduler's prefix index turns the
//!   shared history into a KV-cache hit (DESIGN.md §7). Overload
//!   extension: `"tenant"`: an opaque string naming the paying tenant
//!   for per-tenant admission quotas (falls back to `session_id`).
//!
//! Error contract (DESIGN.md §9): malformed requests — bad JSON, unknown
//! `class`, out-of-range `priority`/`max_tokens`, overlong prompt — are
//! **400** and retrying them can never help; admission refusals — rate
//! limit, tenant quota, load shed, ring backpressure — are **429** with
//! a computed `retry_after_ms` in the body.
//! * `GET /health` — liveness.
//! * `GET /metrics` — scheduler + frontend counters, text format.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::frontend::overload::Rejected;
use crate::frontend::tracker::TokenEvent;
use crate::frontend::{DpuFrontend, RequestClass};
use crate::gpu::SchedulerStats;
use crate::tokenizer::Detokenizer;
use crate::util::json::{parse, Json};

/// Documented upper bound for `max_tokens`. The frontend additionally
/// clamps to the ring's output-arena capacity; this cap exists so the
/// wire-level u64 → u32 conversion is validated, never truncating.
pub const MAX_TOKENS_LIMIT: u64 = 1 << 20;

pub struct HttpServer {
    pub addr: std::net::SocketAddr,
    // lint: atomic(stop) flag
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
    // lint: atomic(requests_served) counter
    pub requests_served: Arc<AtomicU64>,
}

impl HttpServer {
    /// Bind + serve on a pool of acceptor->worker threads.
    pub fn serve(
        bind: &str,
        frontend: Arc<DpuFrontend>,
        stats: Arc<SchedulerStats>,
    ) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let requests_served = Arc::new(AtomicU64::new(0));
        let (stop2, served2) = (stop.clone(), requests_served.clone());
        let handle = std::thread::Builder::new()
            .name("http-acceptor".into())
            .spawn(move || {
                while !stop2.load(Ordering::Acquire) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let fe = frontend.clone();
                            let st = stats.clone();
                            let served = served2.clone();
                            std::thread::spawn(move || {
                                let _ = handle_conn(stream, fe, st, served);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(1));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(HttpServer { addr, stop, handle: Some(handle), requests_served })
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct Request {
    method: String,
    path: String,
    body: Vec<u8>,
}

fn read_request(stream: &mut TcpStream) -> std::io::Result<Option<Request>> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("/").to_string();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            break;
        }
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_length.min(16 * 1024 * 1024)];
    reader.read_exact(&mut body)?;
    Ok(Some(Request { method, path, body }))
}

fn respond(stream: &mut TcpStream, code: u16, content_type: &str, body: &str) -> std::io::Result<()> {
    let status = match code {
        200 => "200 OK",
        400 => "400 Bad Request",
        404 => "404 Not Found",
        429 => "429 Too Many Requests",
        _ => "500 Internal Server Error",
    };
    write!(
        stream,
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
}

fn handle_conn(
    mut stream: TcpStream,
    frontend: Arc<DpuFrontend>,
    stats: Arc<SchedulerStats>,
    served: Arc<AtomicU64>,
) -> std::io::Result<()> {
    let Some(req) = read_request(&mut stream)? else { return Ok(()) };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => respond(&mut stream, 200, "application/json", "{\"status\":\"ok\"}"),
        ("GET", "/metrics") => {
            let mut body = format!(
                "# blink scheduler\n{}\n# frontend\nfree_slots {}\n# overload\n",
                stats.summary(),
                frontend.approx_free_slots()
            );
            let gate = frontend.gate();
            body.push_str(&format!("overload_enabled {}\n", gate.enabled() as u32));
            for t in gate.tenant_stats() {
                let total = t.admitted + t.rejected;
                let rate = if total == 0 { 1.0 } else { t.admitted as f64 / total as f64 };
                body.push_str(&format!(
                    "tenant_admission{{key=\"{:#x}\"}} admitted={} rejected={} rate={:.3}\n",
                    t.key, t.admitted, t.rejected, rate
                ));
            }
            respond(&mut stream, 200, "text/plain", &body)
        }
        ("POST", "/v1/completions") => {
            served.fetch_add(1, Ordering::Relaxed);
            handle_completion(&mut stream, &frontend, &req.body)
        }
        _ => respond(&mut stream, 404, "application/json", "{\"error\":\"not found\"}"),
    }
}

/// Request-class fields from the completion body (see module docs).
/// Unknown `"class"` values are an error — silently downgrading a typo'd
/// "interactive" to batch would drop its scheduling preference with a
/// 200 response.
fn parse_request_class(obj: &Json) -> Result<RequestClass, String> {
    let mut class = match obj.get("class") {
        None => RequestClass::default(),
        Some(c) => match c.as_str() {
            // The shorthand implies the canonical interactive SLO (300 ms),
            // overridable by an explicit ttft_deadline_ms below.
            Some(s) if s.eq_ignore_ascii_case("interactive") => {
                RequestClass::interactive(300_000)
            }
            Some(s) if s.eq_ignore_ascii_case("batch") => RequestClass::default(),
            Some(other) => return Err(format!("unknown class {other:?} (interactive|batch)")),
            None => return Err("class must be a string (interactive|batch)".into()),
        },
    };
    if let Some(p) = obj.get("priority") {
        match p.as_u64() {
            Some(v) if v <= 7 => class.priority = v as u32,
            _ => return Err("priority must be an integer 0-7".into()),
        }
    }
    if let Some(m) = obj.get("ttft_deadline_ms") {
        match m.as_f64() {
            // Clamp to an hour: beyond that a deadline is meaningless and
            // unclamped client values risk µs-conversion overflow.
            Some(ms) if ms > 0.0 => {
                class.ttft_budget_us = (ms.min(3_600_000.0) * 1_000.0) as u64
            }
            Some(_) => {} // 0 or negative: no deadline
            None => return Err("ttft_deadline_ms must be a number".into()),
        }
    }
    Ok(class)
}

fn handle_completion(
    stream: &mut TcpStream,
    frontend: &DpuFrontend,
    body: &[u8],
) -> std::io::Result<()> {
    let parsed = std::str::from_utf8(body).ok().and_then(|s| parse(s).ok());
    let Some(obj) = parsed else {
        return respond(stream, 400, "application/json", "{\"error\":\"bad json\"}");
    };
    let Some(prompt) = obj.get("prompt").and_then(|p| p.as_str()) else {
        return respond(stream, 400, "application/json", "{\"error\":\"missing prompt\"}");
    };
    let max_tokens = match obj.get("max_tokens") {
        None => 16u32,
        Some(m) => match m.as_u64() {
            // The lower edge guards PR 4's fail-fast invariant (a
            // max_new == 0 lane must never exist); the upper edge keeps
            // the u64→u32 conversion lossless instead of silently
            // wrapping 4294967297 to 1.
            Some(v) if (1..=MAX_TOKENS_LIMIT).contains(&v) => v as u32,
            _ => {
                let msg = Json::obj(vec![(
                    "error",
                    Json::Str(format!(
                        "max_tokens must be an integer in 1..={MAX_TOKENS_LIMIT}"
                    )),
                )])
                .to_string();
                return respond(stream, 400, "application/json", &msg);
            }
        },
    };
    let stream_mode = obj.get("stream").and_then(|s| s.as_bool()).unwrap_or(false);
    let class = match parse_request_class(&obj) {
        Ok(c) => c,
        Err(e) => {
            let msg = Json::obj(vec![("error", Json::Str(e))]).to_string();
            return respond(stream, 400, "application/json", &msg);
        }
    };
    let session: Option<String> = match obj.get("session_id") {
        None => None,
        Some(s) => match s.as_str() {
            Some(v) if !v.is_empty() => Some(v.to_string()),
            _ => {
                let msg = Json::obj(vec![(
                    "error",
                    Json::Str("session_id must be a non-empty string".into()),
                )])
                .to_string();
                return respond(stream, 400, "application/json", &msg);
            }
        },
    };
    let tenant: Option<String> = match obj.get("tenant") {
        None => None,
        Some(t) => match t.as_str() {
            Some(v) if !v.is_empty() => Some(v.to_string()),
            _ => {
                let msg = Json::obj(vec![(
                    "error",
                    Json::Str("tenant must be a non-empty string".into()),
                )])
                .to_string();
                return respond(stream, 400, "application/json", &msg);
            }
        },
    };

    let handle = match frontend.submit_text_tenant(
        session.as_deref(),
        tenant.as_deref(),
        prompt,
        max_tokens,
        class,
    ) {
        Ok(h) => h,
        Err(Rejected::Client(e)) => {
            let msg = Json::obj(vec![("error", Json::Str(e))]).to_string();
            return respond(stream, 400, "application/json", &msg);
        }
        Err(Rejected::Overload { reason, retry_after_ms }) => {
            let msg = Json::obj(vec![
                ("error", Json::Str(reason)),
                ("retry_after_ms", Json::Num(retry_after_ms as f64)),
            ])
            .to_string();
            return respond(stream, 429, "application/json", &msg);
        }
    };
    let id = format!("cmpl-{}", handle.request_id);

    if stream_mode {
        // The streaming loop runs in a closure so a transport error
        // (client disconnect mid-stream) can poison the session before
        // propagating: the turn's text is in the history but the client
        // never saw the full answer — the next turn must be refused, not
        // served against a transcript the client doesn't have.
        let streamed = (|| -> std::io::Result<()> {
            write!(
                stream,
                "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n"
            )?;
            let mut detok = Detokenizer::new();
            let mut generated: Vec<u32> = Vec::new();
            loop {
                match handle.rx.recv() {
                    Ok(TokenEvent::Token(t)) => {
                        generated.push(t);
                        let text = detok.push(&frontend.vocab, t);
                        if text.is_empty() {
                            continue; // mid-codepoint
                        }
                        let chunk = Json::obj(vec![
                            ("id", Json::Str(id.clone())),
                            ("object", Json::Str("text_completion.chunk".into())),
                            (
                                "choices",
                                Json::Arr(vec![Json::obj(vec![
                                    ("index", Json::Num(0.0)),
                                    ("text", Json::Str(text)),
                                ])]),
                            ),
                        ]);
                        write!(stream, "data: {}\n\n", chunk.to_string())?;
                        stream.flush()?;
                    }
                    Ok(TokenEvent::Done) => {
                        if let Some(sid) = &session {
                            frontend.record_session_reply(sid, &generated);
                        }
                        let tail = detok.finish();
                        if !tail.is_empty() {
                            let chunk = Json::obj(vec![
                                ("id", Json::Str(id.clone())),
                                (
                                    "choices",
                                    Json::Arr(vec![Json::obj(vec![
                                        ("index", Json::Num(0.0)),
                                        ("text", Json::Str(tail)),
                                    ])]),
                                ),
                            ]);
                            write!(stream, "data: {}\n\n", chunk.to_string())?;
                        }
                        write!(stream, "data: [DONE]\n\n")?;
                        return stream.flush();
                    }
                    Ok(TokenEvent::Failed) | Err(_) => {
                        // The turn's text is already in the session history
                        // but was never answered: poison the session so the
                        // next turn errors instead of replaying a
                        // conversation that did not happen.
                        if let Some(sid) = &session {
                            frontend.poison_session(sid);
                        }
                        write!(stream, "data: {{\"error\":\"generation failed\"}}\n\n")?;
                        write!(stream, "data: [DONE]\n\n")?;
                        return stream.flush();
                    }
                }
            }
        })();
        if streamed.is_err() {
            // Transport died mid-stream: refuse the session's next turn
            // rather than serve it against an answer the client never
            // fully received.
            if let Some(sid) = &session {
                frontend.poison_session(sid);
            }
        }
        streamed
    } else {
        let prompt_tokens = handle.prompt_tokens;
        let effective_max_new = handle.max_new;
        match handle.collect() {
            Ok(tokens) => {
                if let Some(sid) = &session {
                    frontend.record_session_reply(sid, &tokens);
                }
                let text = crate::tokenizer::decode(&frontend.vocab, &tokens);
                let resp = Json::obj(vec![
                    ("id", Json::Str(id)),
                    ("object", Json::Str("text_completion".into())),
                    ("model", Json::Str("blink-tiny".into())),
                    (
                        "choices",
                        Json::Arr(vec![Json::obj(vec![
                            ("index", Json::Num(0.0)),
                            ("text", Json::Str(text)),
                            ("finish_reason", Json::Str("length".into())),
                        ])]),
                    ),
                    (
                        "usage",
                        Json::obj(vec![
                            ("prompt_tokens", Json::Num(prompt_tokens as f64)),
                            ("completion_tokens", Json::Num(tokens.len() as f64)),
                            // The *effective* output budget: a
                            // shed-degraded admission reports its capped
                            // value here.
                            ("max_new", Json::Num(effective_max_new as f64)),
                        ]),
                    ),
                ]);
                respond(stream, 200, "application/json", &resp.to_string())
            }
            Err(e) => {
                // See the SSE failure path: refuse further turns on a
                // history that contains an unanswered user turn.
                if let Some(sid) = &session {
                    frontend.poison_session(sid);
                }
                let msg = Json::obj(vec![("error", Json::Str(e))]).to_string();
                respond(stream, 500, "application/json", &msg)
            }
        }
    }
}
