//! Device-plane primitives (paper §4.2): device-side graph launch modes,
//! the 120-launch fire-and-forget budget with window-based tail-launch
//! recovery, sub-10 µs spin delays, and the polled completion buffer that
//! replaces host-side completion callbacks.
//!
//! The latency constants are the paper's own microbenchmarks: ≈2 µs
//! fire-and-forget, ≈5.5 µs tail launch, 11–17 µs host launch. They drive
//! both the live scheduler (as spin delays, since OS sleep granularity is
//! far coarser) and the discrete-event simulator's cost model.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// CUDA runtime limit on outstanding fire-and-forget launches from a
/// single parent graph execution (paper §4.2 "the 120-launch hard limit").
pub const FNF_LAUNCH_LIMIT: u32 = 120;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaunchMode {
    FireAndForget,
    Tail,
    Host,
}

/// Paper-measured launch latencies in microseconds.
#[derive(Debug, Clone, Copy)]
pub struct LaunchLatencies {
    pub fnf_us: f64,
    pub tail_us: f64,
    pub host_us: f64,
}

impl Default for LaunchLatencies {
    fn default() -> Self {
        LaunchLatencies { fnf_us: 2.0, tail_us: 5.5, host_us: 14.0 }
    }
}

impl LaunchLatencies {
    pub fn zero() -> Self {
        LaunchLatencies { fnf_us: 0.0, tail_us: 0.0, host_us: 0.0 }
    }

    pub fn for_mode(&self, mode: LaunchMode) -> f64 {
        match mode {
            LaunchMode::FireAndForget => self.fnf_us,
            LaunchMode::Tail => self.tail_us,
            LaunchMode::Host => self.host_us,
        }
    }
}

/// Busy-wait for `us` microseconds. OS sleep granularity (≥50 µs) cannot
/// express the 2 µs launch costs, so the device plane spins — which is
/// also what a persistent CUDA kernel does.
pub fn spin_us(us: f64) {
    if us <= 0.0 {
        return;
    }
    let start = Instant::now();
    let target = std::time::Duration::from_nanos((us * 1000.0) as u64);
    while start.elapsed() < target {
        std::hint::spin_loop();
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WindowExhausted;

/// The fire-and-forget launch window + tail-launch recovery protocol.
///
/// A monotone launch counter lives in "shared memory"; on reaching the
/// 120-launch limit the scheduler issues a single tail launch that
/// atomically replaces the running parent graph with a fresh instance.
/// All logical state (ring buffer pointers, KV-cache metadata, in-flight
/// requests) lives in persistent GPU memory and survives re-instantiation
/// — in this codebase that state is everything owned by `crate::gpu`,
/// which deliberately keeps no state inside the window object itself.
#[derive(Debug)]
pub struct LaunchWindow {
    limit: u32,
    counter: u32,
    latencies: LaunchLatencies,
    apply_delays: bool,
    // Telemetry.
    pub fnf_launches: u64,
    pub tail_relaunches: u64,
    pub launch_overhead_us: f64,
}

impl LaunchWindow {
    pub fn new(latencies: LaunchLatencies, apply_delays: bool) -> LaunchWindow {
        LaunchWindow {
            limit: FNF_LAUNCH_LIMIT,
            counter: 0,
            latencies,
            apply_delays,
            fnf_launches: 0,
            tail_relaunches: 0,
            launch_overhead_us: 0.0,
        }
    }

    #[cfg(test)]
    pub fn with_limit(limit: u32) -> LaunchWindow {
        let mut w = LaunchWindow::new(LaunchLatencies::zero(), false);
        w.limit = limit;
        w
    }

    /// Remaining fire-and-forget launches before a tail relaunch is
    /// required — admission condition (iii) of continuous batching checks
    /// this headroom before pausing decode for an inline prefill.
    pub fn headroom(&self) -> u32 {
        self.limit - self.counter
    }

    /// Launch a child graph fire-and-forget. Fails if the window is
    /// exhausted (the caller must `tail_relaunch` first; launching past
    /// the limit is undefined behavior on real hardware, so we refuse).
    pub fn fnf_launch(&mut self) -> Result<(), WindowExhausted> {
        if self.counter >= self.limit {
            return Err(WindowExhausted);
        }
        self.counter += 1;
        self.fnf_launches += 1;
        self.launch_overhead_us += self.latencies.fnf_us;
        if self.apply_delays {
            spin_us(self.latencies.fnf_us);
        }
        Ok(())
    }

    /// Ensure at least `needed` headroom, tail-relaunching if necessary.
    /// Returns true if a relaunch happened.
    pub fn ensure_headroom(&mut self, needed: u32) -> bool {
        if self.headroom() < needed {
            self.tail_relaunch();
            true
        } else {
            false
        }
    }

    /// The recovery step: one tail launch atomically replaces the parent
    /// graph execution with a fresh instance; the counter resets and the
    /// scheduling loop resumes from the same logical point.
    pub fn tail_relaunch(&mut self) {
        self.counter = 0;
        self.tail_relaunches += 1;
        self.launch_overhead_us += self.latencies.tail_us;
        if self.apply_delays {
            spin_us(self.latencies.tail_us);
        }
    }

    /// Amortized launch overhead per child launch so far (paper:
    /// <0.03 µs/step added by the window protocol vs. pure FnF).
    pub fn amortized_overhead_us(&self) -> f64 {
        if self.fnf_launches == 0 {
            0.0
        } else {
            self.launch_overhead_us / self.fnf_launches as f64
        }
    }
}

/// The launch doorbell: a single-slot rendezvous between the persistent
/// scheduler and the executor ("SMs"), replacing a heap-backed channel.
///
/// The scheduler's launch protocol is strictly serialized — it never
/// issues a second launch before polling the previous one's completion
/// buffer — so a one-command slot is exactly the capacity the protocol
/// needs, and ringing the doorbell allocates nothing (an mpsc send heap-
/// allocates a queue node per command, which is precisely the kind of
/// steady-state host-heap traffic the zero-allocation control loop
/// forbids). `ring` parks only in the can't-happen case of a command
/// already armed; `recv` parks until armed or closed.
pub struct Doorbell<T> {
    inner: Mutex<DoorbellInner<T>>,
    cv: Condvar,
}

struct DoorbellInner<T> {
    cmd: Option<T>,
    closed: bool,
}

impl<T> Default for Doorbell<T> {
    fn default() -> Self {
        Doorbell::new()
    }
}

impl<T> Doorbell<T> {
    pub fn new() -> Doorbell<T> {
        Doorbell {
            inner: Mutex::new(DoorbellInner { cmd: None, closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Arm the doorbell with one command. Returns false (dropping the
    /// command) if the doorbell is closed. Blocks while a previous
    /// command is still armed — unreachable under the serialized
    /// launch/poll protocol, but safe if a caller violates it.
    pub fn ring(&self, cmd: T) -> bool {
        let mut g = self.inner.lock().expect("doorbell poisoned");
        loop {
            if g.closed {
                return false;
            }
            if g.cmd.is_none() {
                g.cmd = Some(cmd);
                self.cv.notify_all();
                return true;
            }
            g = self.cv.wait(g).expect("doorbell poisoned");
        }
    }

    /// Executor side: park until a command is armed (Some) or the
    /// doorbell closes with no command pending (None).
    pub fn recv(&self) -> Option<T> {
        let mut g = self.inner.lock().expect("doorbell poisoned");
        loop {
            if let Some(cmd) = g.cmd.take() {
                self.cv.notify_all();
                return Some(cmd);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).expect("doorbell poisoned");
        }
    }

    /// Close the doorbell: wakes a parked `recv` (which drains any armed
    /// command first, then returns None) and makes future `ring`s no-ops.
    pub fn close(&self) {
        let mut g = self.inner.lock().expect("doorbell poisoned");
        g.closed = true;
        self.cv.notify_all();
    }
}

/// Device-polled completion buffer (paper §4.2 "Completion detection").
///
/// Fire-and-forget launches deliver no callback; the inference graph's
/// final sampling op writes the per-lane tokens and bumps the epoch, and
/// the persistent scheduler polls the epoch. Release/acquire pairing on
/// `epoch` guarantees token visibility, mirroring the device memory
/// fences in the CUDA implementation.
pub struct CompletionBuffer {
    // lint: atomic(epoch) observe=Acquire rmw=Release # completion edge:
    // the Release bump publishes the token (and failure) stores below it;
    // the polling scheduler's Acquire load receives them. Same contract
    // as the launch arena's epoch — it is the same protocol, reversed.
    epoch: AtomicU64,
    // lint: atomic(tokens) plane # per-lane cells published by the epoch.
    tokens: Vec<AtomicU32>,
    /// Set when the producing executor hit an error (poisons the poll).
    // lint: atomic(failed) publish=Release observe=Acquire # failure bit;
    // Release so a poller that sees it also sees everything the failing
    // executor did first.
    failed: AtomicU32,
}

impl CompletionBuffer {
    pub fn new(max_lanes: usize) -> CompletionBuffer {
        CompletionBuffer {
            epoch: AtomicU64::new(0),
            tokens: (0..max_lanes).map(|_| AtomicU32::new(0)).collect(),
            failed: AtomicU32::new(0),
        }
    }

    /// Executor side: publish `tokens` for this step and bump the epoch.
    // lint: no_alloc no_panic
    pub fn publish(&self, tokens: &[u32]) {
        for (i, t) in tokens.iter().enumerate() {
            self.tokens[i].store(*t, Ordering::Relaxed);
        }
        self.epoch.fetch_add(1, Ordering::Release);
    }

    // lint: no_alloc no_panic
    pub fn fail(&self) {
        self.failed.store(1, Ordering::Release);
        self.epoch.fetch_add(1, Ordering::Release);
    }

    // lint: no_alloc no_panic
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Scheduler side: spin until the epoch advances past `last_seen`,
    /// then read `n` tokens. Returns None on executor failure.
    pub fn poll_wait(&self, last_seen: u64, n: usize) -> Option<Vec<u32>> {
        let mut out = Vec::with_capacity(n);
        self.poll_wait_into(last_seen, n, &mut out).then_some(out)
    }

    /// Allocation-free variant of [`CompletionBuffer::poll_wait`]: spin
    /// until the epoch advances, then fill the caller's scratch with the
    /// `n` tokens (cleared first; no reallocation once the scratch has
    /// grown to the widest grid). Returns false on executor failure.
    // lint: no_alloc no_panic # `out.extend` fills persistent scratch;
    // the hotloop_alloc runtime pin covers the reallocation case.
    pub fn poll_wait_into(&self, last_seen: u64, n: usize, out: &mut Vec<u32>) -> bool {
        out.clear();
        while self.epoch.load(Ordering::Acquire) <= last_seen {
            std::hint::spin_loop();
        }
        if self.failed.load(Ordering::Acquire) != 0 {
            return false;
        }
        out.extend((0..n).map(|i| self.tokens[i].load(Ordering::Relaxed)));
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_enforces_limit() {
        let mut w = LaunchWindow::with_limit(3);
        assert!(w.fnf_launch().is_ok());
        assert!(w.fnf_launch().is_ok());
        assert!(w.fnf_launch().is_ok());
        assert_eq!(w.fnf_launch(), Err(WindowExhausted));
        w.tail_relaunch();
        assert!(w.fnf_launch().is_ok());
        assert_eq!(w.fnf_launches, 4);
        assert_eq!(w.tail_relaunches, 1);
    }

    #[test]
    fn ensure_headroom_relaunches_exactly_when_needed() {
        let mut w = LaunchWindow::with_limit(5);
        for _ in 0..4 {
            w.fnf_launch().unwrap();
        }
        assert_eq!(w.headroom(), 1);
        assert!(!w.ensure_headroom(1));
        assert!(w.ensure_headroom(2));
        assert_eq!(w.headroom(), 5);
    }

    #[test]
    fn amortized_overhead_small() {
        // Paper: 120 FnF (2 µs) + 1 tail (5.5 µs) per window ⇒ the tail
        // adds < 0.05 µs per step.
        let mut w = LaunchWindow::new(LaunchLatencies::default(), false);
        for _ in 0..10 {
            while w.fnf_launch().is_ok() {}
            w.tail_relaunch();
        }
        let amortized_tail =
            w.tail_relaunches as f64 * 5.5 / w.fnf_launches as f64;
        assert!(amortized_tail < 0.05, "amortized tail {amortized_tail}");
    }

    #[test]
    fn completion_buffer_epoch_protocol() {
        let cb = std::sync::Arc::new(CompletionBuffer::new(4));
        let cb2 = cb.clone();
        let h = std::thread::spawn(move || {
            cb2.publish(&[9, 8, 7, 6]);
        });
        let toks = cb.poll_wait(0, 4).unwrap();
        assert_eq!(toks, vec![9, 8, 7, 6]);
        h.join().unwrap();
    }

    #[test]
    fn completion_buffer_failure_poisons() {
        let cb = CompletionBuffer::new(1);
        cb.fail();
        assert!(cb.poll_wait(0, 1).is_none());
    }

    #[test]
    fn spin_us_waits() {
        let t = Instant::now();
        spin_us(100.0);
        assert!(t.elapsed().as_micros() >= 100);
    }

    #[test]
    fn poll_wait_into_reuses_scratch_capacity() {
        let cb = CompletionBuffer::new(8);
        let mut scratch: Vec<u32> = Vec::with_capacity(8);
        cb.publish(&[1, 2, 3]);
        assert!(cb.poll_wait_into(0, 3, &mut scratch));
        assert_eq!(scratch, vec![1, 2, 3]);
        let cap = scratch.capacity();
        cb.publish(&[4, 5]);
        assert!(cb.poll_wait_into(1, 2, &mut scratch));
        assert_eq!(scratch, vec![4, 5]);
        assert_eq!(scratch.capacity(), cap, "scratch never reallocates");
        cb.fail();
        assert!(!cb.poll_wait_into(2, 1, &mut scratch));
    }

    #[test]
    fn doorbell_delivers_in_order_and_closes() {
        let bell = std::sync::Arc::new(Doorbell::<u32>::new());
        let bell2 = bell.clone();
        let h = std::thread::spawn(move || {
            let mut got = vec![];
            while let Some(v) = bell2.recv() {
                got.push(v);
            }
            got
        });
        // Serialized protocol: each ring is consumed before the next.
        for v in 0..16u32 {
            assert!(bell.ring(v));
        }
        bell.close();
        assert_eq!(h.join().unwrap(), (0..16).collect::<Vec<u32>>());
        assert!(!bell.ring(99), "ring after close is a dropped no-op");
    }

    #[test]
    fn doorbell_recv_drains_armed_command_before_close_returns_none() {
        let bell = Doorbell::<u8>::new();
        assert!(bell.ring(7));
        bell.close();
        assert_eq!(bell.recv(), Some(7), "armed command survives close");
        assert_eq!(bell.recv(), None);
    }
}
