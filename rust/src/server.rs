//! Top-level assembly: one Blink serving instance (Fig 2's whole picture).
//!
//! `BlinkServer::start` is the host's *provisioning plane* role: it loads
//! the model (executor thread compiles the AOT graphs), allocates the
//! GPU-resident ring buffer, spawns the RDMA engine, the persistent
//! scheduler and the DPU frontend — then the host thread is done; the
//! steady-state request path is frontend(DPU) → RDMA → ring buffer →
//! scheduler(GPU) → executor(GPU) and back.

use std::sync::Arc;

use crate::frontend::overload::{OverloadConfig, Rejected};
use crate::frontend::token_reader::ReaderConfig;
use crate::frontend::{DpuFrontend, FrontendConfig, RequestClass, RequestHandle};
use crate::gpu::{Executor, Placement, PolicyKind, PrefixReuse, Scheduler, SchedulerConfig};
use crate::rdma::{RdmaConfig, RdmaEngine};
use crate::ringbuf::{RingBuffer, RingConfig};
use crate::runtime::{artifacts_dir, ModelManifest};
use crate::tokenizer::Vocab;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub model: String,
    pub ring_slots: usize,
    pub placement: Placement,
    pub rdma: RdmaConfig,
    pub apply_launch_delays: bool,
    /// Admission policy for the persistent scheduler (`--policy` on the
    /// CLI). FCFS reproduces the paper.
    pub policy: PolicyKind,
    /// Prefix-aware KV reuse (DESIGN.md §7). `Auto` (the default) turns
    /// reuse on exactly when the artifacts provide offset prefill
    /// graphs, so a hit prefills only its uncached suffix at the correct
    /// positions; without them it falls back to the paper's cold
    /// behavior. `serve --no-prefix-reuse` forces it off.
    pub prefix_reuse: PrefixReuse,
    /// Per-iteration prefill token budget (`--prefill-chunk-tokens`):
    /// prompts whose uncached suffix exceeds it prefill in block-aligned
    /// chunks interleaved with decode steps (DESIGN.md §5). `None` =
    /// the largest offset-graph seq; `Some(0)` = whole-prompt prefill
    /// (the paper's behavior).
    pub prefill_chunk_tokens: Option<usize>,
    /// DPU-side admission gate (DESIGN.md §9): sliding-window rate
    /// limit, per-tenant token buckets, class-aware load shedding.
    /// Disabled by default — the paper's open-loop behavior.
    pub overload: OverloadConfig,
    /// Fixed-k speculative decoding (`--spec-k` on the CLI,
    /// DESIGN.md §11): each decode iteration drafts k tokens per lane
    /// and verifies them in one `decode_verify` launch. Engages only
    /// when the artifacts ship verify graphs at exactly this k; 0 (the
    /// default) is the paper's one-token-per-launch decode.
    pub spec_k: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            model: "blink-tiny".into(),
            // Scaled-down ring (the paper uses 4096 on a 96 GB H100): the
            // tiny model's KV pool bounds concurrency well below this.
            ring_slots: 256,
            placement: Placement::GpuResident,
            rdma: RdmaConfig::default(),
            apply_launch_delays: true,
            policy: PolicyKind::Fcfs,
            prefix_reuse: PrefixReuse::Auto,
            prefill_chunk_tokens: None,
            overload: OverloadConfig::default(),
            spec_k: 0,
        }
    }
}

pub struct BlinkServer {
    pub ring: Arc<RingBuffer>,
    pub rdma: Arc<RdmaEngine>,
    pub frontend: Arc<DpuFrontend>,
    pub scheduler: Scheduler,
    pub manifest: ModelManifest,
}

impl BlinkServer {
    pub fn start(config: ServerConfig) -> anyhow::Result<BlinkServer> {
        let artifacts = artifacts_dir();
        let manifest = ModelManifest::load(&artifacts.join(&config.model).join("manifest.txt"))?;
        let vocab = Arc::new(
            Vocab::load(&artifacts.join("vocab.blink"))
                .map_err(|e| anyhow::anyhow!("vocab: {e}"))?,
        );

        let max_ctx = manifest.max_context();
        let ring = Arc::new(RingBuffer::new(RingConfig {
            num_slots: config.ring_slots,
            max_prompt: max_ctx.min(crate::ringbuf::RingConfig::default().max_prompt),
            max_output: max_ctx.min(crate::ringbuf::RingConfig::default().max_output),
        }));
        let rdma = RdmaEngine::spawn(ring.clone(), config.rdma);

        // Host-assisted initialization: compile graphs, load weights.
        let executor = Executor::spawn(artifacts.clone(), config.model.clone())?;

        let scheduler = Scheduler::spawn(
            ring.clone(),
            executor,
            manifest.clone(),
            SchedulerConfig {
                placement: config.placement.clone(),
                apply_launch_delays: config.apply_launch_delays,
                policy: config.policy,
                prefix_reuse: config.prefix_reuse,
                prefill_chunk_tokens: config.prefill_chunk_tokens,
                spec_k: config.spec_k,
                ..Default::default()
            },
        );

        let frontend = Arc::new(DpuFrontend::new(
            rdma.clone(),
            vocab,
            FrontendConfig {
                num_slots: config.ring_slots,
                max_prompt: ring.config.max_prompt,
                max_output: ring.config.max_output,
                reader: ReaderConfig::default(),
                overload: config.overload,
            },
        ));
        // Gate decisions mirror into the scheduler's stats block so one
        // `/metrics` scrape shows admission and execution side by side.
        frontend.attach_stats(scheduler.stats.clone());

        Ok(BlinkServer { ring, rdma, frontend, scheduler, manifest })
    }

    /// Convenience passthroughs.
    pub fn submit_text(&self, text: &str, max_new: u32) -> Result<RequestHandle, Rejected> {
        self.frontend.submit_text(text, max_new)
    }

    pub fn submit_tokens(&self, toks: &[u32], max_new: u32) -> Result<RequestHandle, Rejected> {
        self.frontend.submit_tokens(toks, max_new)
    }

    pub fn submit_tokens_class(
        &self,
        toks: &[u32],
        max_new: u32,
        class: RequestClass,
    ) -> Result<RequestHandle, Rejected> {
        self.frontend.submit_tokens_class(toks, max_new, class)
    }

    /// Drain in-flight work and stop the scheduler (host is allowed back
    /// on the path only to tear the instance down).
    pub fn shutdown(mut self) {
        self.scheduler.drain_and_stop();
    }
}
