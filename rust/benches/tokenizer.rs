//! Fig 4 microbenchmark form: the three tokenizers on identical text.
use blink::runtime::artifacts_dir;
use blink::tokenizer::baselines::{HeapliteTokenizer, NaiveTokenizer};
use blink::tokenizer::blink::BlinkTokenizer;
use blink::tokenizer::{Tokenizer, Vocab};
use blink::util::timer::bench;
use std::time::Duration;

fn main() {
    let vocab = match Vocab::load(&artifacts_dir().join("vocab.blink")) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("skipping tokenizer bench: {e} (run `make artifacts`)");
            return;
        }
    };
    let blink = BlinkTokenizer::new(&vocab);
    let naive = NaiveTokenizer::new(&vocab);
    let heap = HeapliteTokenizer::new(&vocab);
    let text: String = "the persistent scheduler scans the ring buffer for newly \
                        submitted prompts and claims them via atomic compare and swap "
        .repeat(32); // ~2k tokens
    let budget = Duration::from_millis(500);
    let mut out = Vec::with_capacity(4096);
    for (name, t) in [
        ("tokenizer/blink (flat-hash+SWAR)", &blink as &dyn Tokenizer),
        ("tokenizer/naive-hf (SipHash+Box)", &naive),
        ("tokenizer/heaplite (BinaryHeap)", &heap),
    ] {
        bench(name, 5, budget, || {
            out.clear();
            t.encode(&text, &mut out);
            std::hint::black_box(&out);
        });
    }
    println!("tokens per encode: {}", out.len());
}
