//! Scheduler-plane microbenchmarks that do NOT need artifacts: slot-scan
//! latency at ring scale, KV admission, graph-cache selection.
use blink::graphs::{GraphCache, GraphId, GraphKind, GraphSpec};
use blink::kvcache::{KvConfig, KvManager};
use blink::ringbuf::{RingBuffer, RingConfig};
use blink::util::timer::bench;
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(300);

    // Graph-cache O(1) tightest-fit selection.
    let mut specs = vec![];
    let mut id = 0;
    for b in [1usize, 2, 4] {
        for s in [16usize, 32, 64, 128, 256] {
            specs.push(GraphSpec { id: GraphId(id), name: format!("p{b}_{s}"), kind: GraphKind::Prefill, batch: b, seq: s });
            id += 1;
        }
    }
    for b in [1usize, 2, 4, 8, 16] {
        specs.push(GraphSpec { id: GraphId(id), name: format!("d{b}"), kind: GraphKind::Decode, batch: b, seq: 0 });
        id += 1;
    }
    let cache = GraphCache::new(specs);
    let mut q = 1usize;
    bench("graphs/select_prefill (O(1) LUT)", 100, budget, || {
        q = q % 250 + 1;
        std::hint::black_box(cache.select_prefill(1 + q % 4, q));
    });
    bench("graphs/select_decode", 100, budget, || {
        q = q % 16 + 1;
        std::hint::black_box(cache.select_decode(q));
    });

    // KV admission + release cycle.
    let mut kv = KvManager::new(KvConfig { block_size: 16, num_blocks: 512, max_blocks_per_seq: 32 });
    bench("kvcache/admit+release (4 blocks)", 100, budget, || {
        let c = kv.admit(64, 50, 10).unwrap();
        kv.release(c);
    });

    // Overlapped-scan cost at paper scale with live traffic pattern.
    let rb = RingBuffer::new(RingConfig::default());
    for i in (0..4096).step_by(257) {
        rb.claim_for_write(i);
        rb.write_prompt(i, &[1]);
        rb.submit(i, i as u64, 1, 4, 0);
    }
    bench("scheduler/overlapped_ring_scan(4096 slots)", 100, budget, || {
        std::hint::black_box(rb.scan_pending());
    });

    // The hot-loop variant: same sweep into a persistent scratch.
    let mut scratch: Vec<usize> = Vec::with_capacity(4096);
    bench("scheduler/overlapped_ring_scan_into(4096 slots)", 100, budget, || {
        rb.scan_pending_into(&mut scratch);
        std::hint::black_box(scratch.len());
    });
}
