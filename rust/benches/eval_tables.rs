//! End-to-end benchmark harness: times the DES points behind each paper
//! table/figure (the regeneration itself is `blink eval all`). One bench
//! row per table/figure family.
use blink::sim::costmodel::{LLAMA3_8B, QWEN3_30B_A3B, QWEN3_32B};
use blink::sim::des::{simulate, SimConfig};
use blink::sim::sweep::run_sweep;
use blink::sim::systems::System;
use blink::util::timer::bench;
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(800);
    bench("eval/table1_point (vLLM 7rps 60s)", 1, budget, || {
        std::hint::black_box(simulate(&SimConfig::new(System::Vllm, LLAMA3_8B, 7.0, true)));
    });
    bench("eval/fig1_point (MoE 4rps)", 1, budget, || {
        std::hint::black_box(simulate(&SimConfig::new(System::Blink, QWEN3_30B_A3B, 4.0, false)));
    });
    bench("eval/fig5_point (32B p999)", 1, budget, || {
        std::hint::black_box(simulate(&SimConfig::new(System::Sglang, QWEN3_32B, 2.0, true)));
    });
    let t = std::time::Instant::now();
    let r = run_sweep(&[LLAMA3_8B], 60.0, std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8));
    println!(
        "eval/full_llama_sweep (104 points, 60s windows): {:.2}s wall, sat level {}",
        t.elapsed().as_secs_f64(),
        r.blink_saturation_level("llama3-8b")
    );
    println!("(run `blink eval all --out results/` for the full table/figure regeneration)");
}
