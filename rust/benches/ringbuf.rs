//! Microbenchmarks: ring-buffer hot-path operations (claim, publish,
//! scan). Paper target: full 4096-slot scan in 1-5 µs (§4.2).
use blink::ringbuf::{RingBuffer, RingConfig};
use blink::util::timer::bench;
use std::time::Duration;

fn main() {
    let rb = RingBuffer::new(RingConfig::default()); // 4096 slots
    let budget = Duration::from_millis(400);

    bench("ringbuf/scan_4096_empty (paper: 1-5µs)", 100, budget, || {
        std::hint::black_box(rb.scan_pending());
    });

    // Populate 64 pending slots spread across the ring.
    for i in (0..4096).step_by(64) {
        rb.claim_for_write(i);
        rb.write_prompt(i, &[1, 2, 3]);
        rb.submit(i, i as u64, 3, 8, 0);
    }
    bench("ringbuf/scan_4096_64pending", 100, budget, || {
        std::hint::black_box(rb.scan_pending());
    });

    let rb2 = RingBuffer::new(RingConfig::default());
    let mut slot = 0usize;
    bench("ringbuf/claim+submit+release cycle", 100, budget, || {
        rb2.claim_for_write(slot);
        rb2.write_prompt(slot, &[1, 2, 3, 4]);
        rb2.submit(slot, 1, 4, 4, 0);
        rb2.claim_pending(slot);
        rb2.slot(slot).set_state(blink::ringbuf::SlotState::DecodeProcessing);
        rb2.publish_token(slot, 9);
        rb2.complete(slot);
        rb2.release(slot);
        slot = (slot + 1) % 4096;
    });

    let rb3 = RingBuffer::new(RingConfig::default());
    rb3.claim_for_write(0);
    rb3.write_prompt(0, &[1]);
    rb3.submit(0, 1, 1, 500_000, 0);
    rb3.claim_pending(0);
    rb3.slot(0).set_state(blink::ringbuf::SlotState::DecodeProcessing);
    let mut published = 0u32;
    bench("ringbuf/publish_token", 100, budget, || {
        if published as usize >= rb3.config.max_output {
            return;
        }
        rb3.publish_token(0, published);
        published += 1;
    });
}
