//! Engine hot-path timings: per-graph execution latency on the CPU PJRT
//! client — the L2 §Perf measurement (KV-donation before/after lives in
//! EXPERIMENTS.md §Perf).
use blink::runtime::{artifacts_dir, Engine};
use blink::util::timer::bench;
use std::time::Duration;

fn main() {
    let dir = artifacts_dir();
    if !dir.join("blink-tiny/manifest.txt").exists() {
        eprintln!("skipping engine bench: run `make artifacts`");
        return;
    }
    let mut eng = Engine::load(&dir, "blink-tiny").expect("engine");
    let mbs = eng.manifest.max_blocks_per_seq;
    let budget = Duration::from_secs(3);

    // Prefill b2 s64.
    let g = eng.cache.select_prefill(2, 64).unwrap();
    let mut bt = vec![0i32; 2 * mbs];
    for (i, b) in bt.iter_mut().enumerate().take(8) {
        *b = i as i32 + 1;
    }
    let toks: Vec<i32> = (0..128).map(|i| i % 2048).collect();
    bench("engine/prefill_b2_s64", 3, budget, || {
        std::hint::black_box(eng.execute(g, &bt, &[64, 64], &toks, &[], 1).unwrap());
    });

    // Decode for batch 1 and 8.
    for b in [1usize, 8] {
        let g = eng.cache.select_decode(b).unwrap();
        let mut bt = vec![0i32; b * mbs];
        for lane in 0..b {
            for j in 0..4 {
                bt[lane * mbs + j] = (1 + lane * 4 + j) as i32;
            }
        }
        let sl = vec![40i32; b];
        let tk = vec![7i32; b];
        bench(&format!("engine/decode_b{b} (steady-state step)"), 3, budget, || {
            std::hint::black_box(eng.execute(g, &bt, &sl, &tk, &[], 2).unwrap());
        });
    }
    println!("engine steps executed: {}", eng.steps);
}
