//! Longest-prefix-match cost of the KV prefix index at 10k cached
//! blocks (200 conversations × 50 full blocks): the per-admission work
//! the prefix-reuse path adds to the scheduler loop. Also times the
//! admit/release cycle with sharing. No artifacts needed.

use blink::kvcache::{KvConfig, KvManager};
use blink::util::timer::bench;
use std::time::Duration;

const BS: usize = 16;
const SESSIONS: u32 = 200;
const BLOCKS_PER_SESSION: usize = 50;

/// Deterministic per-session token stream, `n` tokens.
fn session_tokens(session: u32, n: usize) -> Vec<u32> {
    (0..n as u32).map(|i| session.wrapping_mul(1_000_003).wrapping_add(i)).collect()
}

fn main() {
    let budget = Duration::from_millis(300);
    let cfg = KvConfig {
        block_size: BS,
        // Room for every session's full reservation plus slack, so the
        // bench measures lookup cost, not eviction churn.
        num_blocks: SESSIONS as usize * (BLOCKS_PER_SESSION + 2) + 64,
        max_blocks_per_seq: BLOCKS_PER_SESSION + 2,
    };
    let mut m = KvManager::new(cfg);

    // Populate: 200 sessions × 50 indexable blocks = 10_000 cached
    // blocks, all parked (refcount 0) like a steady-state prefix cache.
    let prompt_len = BS * BLOCKS_PER_SESSION + 1; // +1 keeps a suffix token
    let mut held = vec![];
    for s in 0..SESSIONS {
        let toks = session_tokens(s, prompt_len);
        let cache = m.admit_reuse(&toks, prompt_len, 4).expect("pool sized for the working set");
        m.index_prompt(&cache, &toks); // prefill "succeeded": commit
        held.push(cache);
    }
    for cache in held {
        m.release(cache);
    }
    println!(
        "indexed blocks: {} (evictable {}, free {})",
        m.stats.indexed_blocks,
        m.evictable_blocks(),
        m.free_blocks()
    );

    // Full-depth hit: walks all 50 blocks of one session's chain.
    let hit_prompt = session_tokens(SESSIONS / 2, prompt_len);
    bench(
        &format!("prefix/match hit ({BLOCKS_PER_SESSION} blocks @ 10k cached)"),
        100,
        budget,
        || {
            let pm = m.match_prefix(&hit_prompt);
            assert_eq!(pm.blocks.len(), BLOCKS_PER_SESSION);
            std::hint::black_box(pm);
        },
    );

    // First-block miss: the cold-prompt fast path (one hash + probe).
    let miss_prompt = session_tokens(SESSIONS + 7, prompt_len);
    bench("prefix/match miss (cold prompt @ 10k cached)", 100, budget, || {
        let pm = m.match_prefix(&miss_prompt);
        assert_eq!(pm.blocks.len(), 0);
        std::hint::black_box(pm);
    });

    // Mid-chain divergence: shared first half, forked second half.
    let mut fork_prompt = session_tokens(SESSIONS / 2, prompt_len);
    for t in fork_prompt.iter_mut().skip(BS * BLOCKS_PER_SESSION / 2) {
        *t ^= 0x8000_0000;
    }
    bench("prefix/match fork (25/50 blocks @ 10k cached)", 100, budget, || {
        let pm = m.match_prefix(&fork_prompt);
        assert_eq!(pm.blocks.len(), BLOCKS_PER_SESSION / 2);
        std::hint::black_box(pm);
    });

    // End-to-end admit(hit)+release cycle — the scheduler's actual
    // per-admission reuse cost (match + refcount + tail reservation).
    bench("prefix/admit+release hit cycle", 50, budget, || {
        let cache = m.admit_reuse(&hit_prompt, BS, 4).expect("admit");
        std::hint::black_box(&cache);
        m.release(cache);
    });
}
