//! Launch-window protocol overhead (paper §4.2: tail relaunch adds
//! <0.03 µs amortized per decode step).
use blink::devsim::{LaunchLatencies, LaunchWindow};
use blink::util::timer::bench;
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(300);
    let mut w = LaunchWindow::new(LaunchLatencies::zero(), false);
    bench("launch_window/fnf+auto_recovery (bookkeeping only)", 100, budget, || {
        if w.fnf_launch().is_err() {
            w.tail_relaunch();
            w.fnf_launch().unwrap();
        }
    });
    println!(
        "fnf={} tail={} amortized_overhead={:.4}µs (model constants: fnf 2µs, tail 5.5µs)",
        w.fnf_launches,
        w.tail_relaunches,
        (w.fnf_launches as f64 * 2.0 + w.tail_relaunches as f64 * 5.5)
            / w.fnf_launches.max(1) as f64
            - 2.0
    );

    // With the paper's spin-delay constants applied.
    let mut w2 = LaunchWindow::new(LaunchLatencies::default(), true);
    bench("launch_window/fnf with 2µs device-launch spin", 10, budget, || {
        if w2.fnf_launch().is_err() {
            w2.tail_relaunch();
            w2.fnf_launch().unwrap();
        }
    });
}
