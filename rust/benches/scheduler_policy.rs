//! Candidate-selection cost of the four admission policies at paper ring
//! scale (4096 slots): scan → snapshot → policy order, the per-iteration
//! work the staged pipeline adds over a raw FCFS scan. No artifacts
//! needed.

use blink::gpu::policy::{
    AdmissionPolicy, Candidate, Fcfs, PriorityAged, ShortestPromptFirst, SloAware,
};
use blink::ringbuf::{RingBuffer, RingConfig, SubmitMeta};
use blink::util::rng::Rng;
use blink::util::timer::bench;
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(300);
    let rb = RingBuffer::new(RingConfig::default()); // 4096 slots

    // Live-traffic pattern: ~10% of the ring pending, mixed classes.
    let mut rng = Rng::new(0xBE7C);
    for i in (0..4096).step_by(10) {
        rb.claim_for_write(i);
        rb.write_prompt(i, &[1]);
        rb.submit_with_meta(
            i,
            &SubmitMeta {
                request_id: i as u64,
                prompt_len: 1 + rng.below(512) as u32,
                max_new: 16,
                seed: 0,
                priority: rng.below(8) as u32,
                ttft_budget_us: if rng.below(2) == 0 { 0 } else { 1_000 + rng.below(1 << 20) },
                session_id: 0,
            },
        );
    }
    let pending = rb.scan_pending();
    println!("pending slots: {}", pending.len());

    bench("policy/scan+snapshot (4096 slots)", 100, budget, || {
        let pending = rb.scan_pending();
        std::hint::black_box(Candidate::collect(&rb, &pending));
    });

    let base = Candidate::collect(&rb, &pending);
    let now = blink::util::timer::now_us();
    let policies: [(&str, Box<dyn AdmissionPolicy>); 4] = [
        ("fcfs", Box::new(Fcfs)),
        ("priority-aged", Box::new(PriorityAged::default())),
        ("sjf", Box::new(ShortestPromptFirst)),
        ("slo", Box::new(SloAware::default())),
    ];
    for (name, policy) in &policies {
        bench(&format!("policy/order {name} ({} cands)", base.len()), 100, budget, || {
            let mut cands = base.clone();
            policy.order(&mut cands, now);
            std::hint::black_box(&cands);
        });
    }

    // End-to-end selection: scan + snapshot + order, per policy.
    for (name, policy) in &policies {
        bench(&format!("policy/scan+order {name} (4096 slots)"), 100, budget, || {
            let pending = rb.scan_pending();
            let mut cands = Candidate::collect(&rb, &pending);
            policy.order(&mut cands, now);
            std::hint::black_box(&cands);
        });
    }
}
