//! Cost of the speculative retire path (DESIGN.md §11), two layers:
//!
//! 1. **Retire-core microbench** — the per-iteration work the verify
//!    retire pass adds over plain decode, isolated: advance each lane's
//!    cache by the full w = k + 1 window, pick a variable-length
//!    accepted prefix, and roll the rejected tail back with
//!    [`KvManager::truncate_tail`], at batch 1 / 32 / 256. Rollback is
//!    pure pointer math (blocks stay reserved — invariant 5), so this
//!    must stay in the tens-of-nanoseconds-per-lane range.
//! 2. **End-to-end iteration cost** — the full speculative control loop
//!    (draft → k-wide verify staging → doorbell → w-wide poll →
//!    variable-length prefix retire with rollback) on the zero-cost
//!    modeled executor at batch 1 / 32 / 256, against the plain-decode
//!    loop on the same manifest, reported as µs per iteration *and* per
//!    emitted token — the orchestration overhead speculation must
//!    amortize before any GPU-side win counts.
//!
//! `--test` runs a seconds-scale smoke of both layers (the CI
//! bench-smoke step: `cargo bench --bench verify_retire -- --test`), so
//! the bench itself cannot bit-rot.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use blink::gpu::{Executor, ModeledCost, PrefixReuse, Scheduler, SchedulerConfig};
use blink::kvcache::{KvConfig, KvManager};
use blink::ringbuf::{RingBuffer, RingConfig, SlotState};
use blink::runtime::ModelManifest;
use blink::util::timer::bench;

const BATCHES: [usize; 3] = [1, 32, 256];
const K: usize = 4;

/// Layer 1: the retire-core delta. Each timed iteration plays one
/// verify retire across the whole batch: optimistic w-token advance,
/// variable accepted prefix (a cheap hash stands in for the accept
/// comparison's outcome), tail rollback. Caches wrap back to the prompt
/// length before the reservation span runs out — also via
/// `truncate_tail`, so the wrap exercises the same path it measures.
fn retire_core_bench(budget: Duration) {
    println!("== verify retire core: w-advance + variable prefix + KV tail rollback ==");
    let w = K + 1;
    for &batch in &BATCHES {
        let mut kv = KvManager::new(KvConfig {
            block_size: 16,
            num_blocks: 64 * batch + 64,
            max_blocks_per_seq: 64,
        });
        let mut caches: Vec<_> = (0..batch)
            .map(|_| kv.admit(16, 16, 1000).expect("bench pool sized for the batch"))
            .collect();
        let mut tick = 0u64;
        let r = bench(&format!("verify_retire/core b={batch} k={K}"), 50, budget, || {
            tick = tick.wrapping_add(1);
            for (i, c) in caches.iter_mut().enumerate() {
                let base = c.cached_len;
                if base + w >= 1000 {
                    kv.truncate_tail(c, 16); // wrap within the reservation
                    continue;
                }
                // Variable-length acceptance, lane- and tick-dependent:
                // the retire pass's per-lane branchiness, not one fixed
                // prefix length hoisted out by the optimizer.
                let accepted = (tick.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> (i % 50)) as usize % w;
                c.cached_len = base + w;
                kv.truncate_tail(c, base + 1 + accepted);
                std::hint::black_box(c.cached_len);
            }
        });
        println!(
            "verify_retire/core b={batch}: {:.0} ns/iter ({:.1} ns/lane)\n",
            r.mean_ns,
            r.mean_ns / batch as f64
        );
    }
}

/// Manifest for the end-to-end layer: decode + k = 4 verify grids up to
/// 256 lanes. Verify outputs are always chain-scored, so `eos_token`
/// sits outside the vocab — no lane may retire mid-measurement. The
/// 8192-token context survives ~2900 speculative iterations at ~2.8
/// accepted tokens per iteration.
fn loop_manifest() -> ModelManifest {
    let mut text = String::from(
        "blink-manifest v1\nmodel verify-retire-bench\nvocab_size 2048\nd_model 64\nn_layers 2\n\
         n_heads 4\nn_kv_heads 2\nd_head 16\nd_ff 128\nblock_size 16\nnum_blocks 140000\n\
         max_blocks_per_seq 512\nn_experts 0\ntop_k 0\neos_token 2048\nmoe 0\n\
         param tok_embed 2048x64 f32\n",
    );
    for b in [1usize, 2, 4, 8, 16, 32, 64, 128, 256] {
        text.push_str(&format!("graph decode_b{b} decode {b} 0\n"));
        text.push_str(&format!("graph decode_verify_b{b}_k{K} decode_verify {b} {K}\n"));
    }
    for b in [1usize, 8, 32] {
        text.push_str(&format!("graph prefill_b{b}_s16 prefill {b} 16\n"));
    }
    ModelManifest::parse(&text).expect("verify retire bench manifest")
}

/// One full control-loop measurement at (batch, spec_k): µs/iteration
/// and µs/emitted-token from the scheduler's own counters.
fn run_loop(m: &ModelManifest, batch: usize, spec_k: usize, measure_steps: u64) -> (f64, f64) {
    let ring = Arc::new(RingBuffer::new(RingConfig {
        num_slots: 256,
        max_prompt: 32,
        max_output: 8192,
    }));
    let executor = Executor::spawn_modeled(m, ModeledCost::zero());
    let mut sched = Scheduler::spawn(
        ring.clone(),
        executor,
        m.clone(),
        SchedulerConfig {
            apply_launch_delays: false,
            prefix_reuse: PrefixReuse::Off,
            spec_k,
            spec_accept: 0.7,
            ..Default::default()
        },
    );
    let stats = sched.stats.clone();
    for slot in 0..batch {
        assert!(ring.claim_for_write(slot));
        let prompt: Vec<u32> = (0..16u32).map(|i| (i * 13 + slot as u32) % 2048).collect();
        ring.write_prompt(slot, &prompt);
        ring.submit(slot, slot as u64, 16, u32::MAX, slot as u32);
    }
    let steps = || stats.decode_steps.load(Ordering::Relaxed);
    let deadline = Instant::now();
    while steps() < 100 {
        assert!(
            deadline.elapsed() < Duration::from_secs(30),
            "warmup stalled: {} lanes pending",
            ring.count_state(SlotState::PrefillPending)
        );
        std::thread::sleep(Duration::from_micros(200));
    }
    let s0 = steps();
    let g0 = stats.tokens_generated.load(Ordering::Relaxed);
    let t0 = Instant::now();
    while steps() < s0 + measure_steps {
        assert!(t0.elapsed() < Duration::from_secs(30), "measurement stalled");
        std::thread::sleep(Duration::from_micros(200));
    }
    let wall_us = t0.elapsed().as_secs_f64() * 1e6;
    let iters = (steps() - s0) as f64;
    let toks = (stats.tokens_generated.load(Ordering::Relaxed) - g0) as f64;
    sched.stop();
    (wall_us / iters, wall_us / toks.max(1.0))
}

/// Layer 2: speculative vs plain control-loop orchestration cost.
fn loop_bench(measure_steps: u64) {
    println!("== end-to-end speculative loop cost (modeled executor, zero graph cost) ==");
    let m = loop_manifest();
    for &batch in &BATCHES {
        let (plain_iter, plain_tok) = run_loop(&m, batch, 0, measure_steps);
        let (spec_iter, spec_tok) = run_loop(&m, batch, K, measure_steps);
        println!(
            "verify_retire/loop b={batch}: plain {plain_iter:.2} µs/iter ({plain_tok:.2} µs/tok) \
             | spec k={K} {spec_iter:.2} µs/iter ({spec_tok:.2} µs/tok) \
             | per-token orchestration ratio {:.2}x",
            spec_tok / plain_tok
        );
    }
    println!();
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    if smoke {
        // CI bench-smoke: exercise both layers end to end in seconds.
        retire_core_bench(Duration::from_millis(20));
        loop_bench(200);
    } else {
        retire_core_bench(Duration::from_millis(300));
        loop_bench(2000);
    }
}
