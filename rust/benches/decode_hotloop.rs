//! Hot-loop cost of the persistent-batch control loop, two layers:
//!
//! 1. **Marshal microbench** — arena staging (`stage_decode`, the
//!    production path: in-place incremental update of persistent planes)
//!    vs the kept-for-comparison rebuild path (`decode_inputs`, fresh
//!    `Vec` quartet per step) at batch 1 / 32 / 256. The printed ratio
//!    is the PR's acceptance number: the arena path must beat the
//!    rebuild path at batch 256.
//! 2. **End-to-end iteration cost** — the full control loop (scan →
//!    stage → doorbell launch → overlapped scan → poll → retire pass)
//!    on the zero-cost modeled executor at batch 1 / 32 / 256, reported
//!    as µs per decode iteration from the scheduler's own step counter.
//!
//! `--test` runs a seconds-scale smoke of both layers (the CI bench-smoke
//! step: `cargo bench --bench decode_hotloop -- --test`), so the bench
//! itself cannot bit-rot.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use blink::gpu::planner::{BatchPlanner, Lane};
use blink::gpu::{Executor, ModeledCost, PrefixReuse, Scheduler, SchedulerConfig};
use blink::graphs::{GraphCache, GraphId, GraphKind, GraphSpec};
use blink::kvcache::SeqCache;
use blink::ringbuf::{RingBuffer, RingConfig, SlotState};
use blink::runtime::ModelManifest;
use blink::util::timer::bench;

const BATCHES: [usize; 3] = [1, 32, 256];
const MBS: usize = 64; // block-table row width for the marshal bench

fn marshal_cache() -> GraphCache {
    let mut specs = vec![];
    for (i, b) in BATCHES.iter().enumerate() {
        specs.push(GraphSpec {
            id: GraphId(i),
            name: format!("decode_b{b}"),
            kind: GraphKind::Decode,
            batch: *b,
            seq: 0,
        });
    }
    GraphCache::new(specs)
}

fn lanes_of(batch: usize) -> Vec<Lane> {
    (0..batch)
        .map(|i| Lane {
            slot: i,
            cache: SeqCache {
                blocks: (1..9usize).map(|j| (i * 8 + j) as u32).collect(),
                cached_len: 100 + i,
                prefix_len: 0,
            },
            generated: 1,
            max_new: 1 << 20,
            last_token: i as i32,
        })
        .collect()
}

/// Layer 1: staging vs rebuilding the decode launch inputs. Each timed
/// iteration first mutates the lane state the way a decode step does
/// (seq_len bump + fresh last_token), so the arena path pays its real
/// incremental work, not a no-op.
fn marshal_bench(budget: Duration) {
    println!("== decode launch marshal: arena (stage_decode) vs rebuild (decode_inputs) ==");
    for &batch in &BATCHES {
        let cache = marshal_cache();
        let mut planner = BatchPlanner::for_cache(&cache, MBS, 16);
        let mut lanes = lanes_of(batch);
        // One full sync (the membership-change case), then steady state.
        planner.stage_decode(&lanes, batch);

        let mut tick = 0i32;
        let arena = bench(&format!("hotloop/arena_stage_decode b={batch}"), 50, budget, || {
            for l in lanes.iter_mut() {
                l.cache.cached_len += 1;
                l.last_token = tick;
            }
            tick = tick.wrapping_add(1);
            std::hint::black_box(planner.stage_decode(&lanes, batch));
        });

        let rebuild = bench(&format!("hotloop/rebuild_decode_inputs b={batch}"), 50, budget, || {
            for l in lanes.iter_mut() {
                l.cache.cached_len += 1;
                l.last_token = tick;
            }
            tick = tick.wrapping_add(1);
            std::hint::black_box(planner.decode_inputs(&lanes, batch));
        });

        println!(
            "hotloop/marshal-ratio b={batch}: rebuild/arena = {:.2}x (arena {:.0} ns, rebuild {:.0} ns)\n",
            rebuild.mean_ns / arena.mean_ns,
            arena.mean_ns,
            rebuild.mean_ns
        );
    }
}

/// Manifest for the end-to-end layer: decode grid up to 256 lanes,
/// prefill grid wide enough to admit them quickly. `max_blocks_per_seq
/// 512` (block 16) bounds the context at 8192 tokens, so lanes survive
/// thousands of iterations before retiring.
fn loop_manifest() -> ModelManifest {
    let mut text = String::from(
        "blink-manifest v1\nmodel hotloop-bench\nvocab_size 2048\nd_model 64\nn_layers 2\n\
         n_heads 4\nn_kv_heads 2\nd_head 16\nd_ff 128\nblock_size 16\nnum_blocks 140000\n\
         max_blocks_per_seq 512\nn_experts 0\ntop_k 0\neos_token 0\nmoe 0\n\
         param tok_embed 2048x64 f32\n",
    );
    for b in [1usize, 2, 4, 8, 16, 32, 64, 128, 256] {
        text.push_str(&format!("graph decode_b{b} decode {b} 0\n"));
    }
    for b in [1usize, 8, 32] {
        text.push_str(&format!("graph prefill_b{b}_s16 prefill {b} 16\n"));
    }
    ModelManifest::parse(&text).expect("hotloop bench manifest")
}

/// Layer 2: µs per control-loop iteration on the zero-cost modeled
/// executor — the pure orchestration cost of a decode step at batch B.
fn loop_bench(measure_steps: u64) {
    println!("== end-to-end control-loop iteration cost (modeled executor, zero graph cost) ==");
    let m = loop_manifest();
    for &batch in &BATCHES {
        let ring = Arc::new(RingBuffer::new(RingConfig {
            num_slots: 256,
            max_prompt: 32,
            max_output: 8192,
        }));
        let executor = Executor::spawn_modeled(&m, ModeledCost::zero());
        let mut sched = Scheduler::spawn(
            ring.clone(),
            executor,
            m.clone(),
            SchedulerConfig {
                apply_launch_delays: false,
                prefix_reuse: PrefixReuse::Off,
                ..Default::default()
            },
        );
        let stats = sched.stats.clone();
        for slot in 0..batch {
            assert!(ring.claim_for_write(slot));
            let prompt: Vec<u32> = (0..16u32).map(|i| (i * 13 + slot as u32) % 2048).collect();
            ring.write_prompt(slot, &prompt);
            ring.submit(slot, slot as u64, 16, u32::MAX, slot as u32);
        }
        let steps = || stats.decode_steps.load(Ordering::Relaxed);
        let deadline = Instant::now();
        // Warmup: all lanes decoding, scratches and arena sync settled.
        while steps() < 100 {
            assert!(
                deadline.elapsed() < Duration::from_secs(30),
                "warmup stalled: {} lanes pending",
                ring.count_state(SlotState::PrefillPending)
            );
            std::thread::sleep(Duration::from_micros(200));
        }
        let s0 = steps();
        let t0 = Instant::now();
        while steps() < s0 + measure_steps {
            assert!(t0.elapsed() < Duration::from_secs(30), "measurement stalled");
            std::thread::sleep(Duration::from_micros(200));
        }
        let iters = steps() - s0;
        let us_per_iter = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;
        println!(
            "hotloop/loop-iteration b={batch}: {us_per_iter:.2} µs/iter over {iters} iters \
             (scheduler-reported p50 {:.2} µs, p99 {:.2} µs)",
            stats.loop_iter_p50_us(),
            stats.loop_iter_p99_us()
        );
        sched.stop();
    }
    println!();
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    if smoke {
        // CI bench-smoke: exercise both layers end to end in seconds.
        marshal_bench(Duration::from_millis(20));
        loop_bench(200);
    } else {
        marshal_bench(Duration::from_millis(300));
        loop_bench(3000);
    }
}
