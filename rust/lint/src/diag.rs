//! Diagnostics: the violation record, deterministic ordering, and the
//! two output formats (human one-liners and the versioned JSON report
//! the CI job uploads).

use crate::Report;

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    /// Stable check id: one of `atomic-undeclared`, `atomic-ordering`,
    /// `atomic-unpaired`, `atomic-conflict`, `contract-syntax`,
    /// `no-alloc`, `no-panic`, `safety-comment`, `allow-unused`.
    pub check: &'static str,
    /// Path relative to the lint root (e.g. `src/ringbuf/slot.rs`).
    pub file: String,
    pub line: usize,
    pub message: String,
    /// The governing contract (`atomic(name) spec`), when one applies.
    pub contract: Option<String>,
}

impl Violation {
    pub fn new(check: &'static str, file: &str, line: usize, message: String) -> Violation {
        Violation { check, file: file.to_string(), line, message, contract: None }
    }

    pub fn with_contract(mut self, contract: String) -> Violation {
        self.contract = Some(contract);
        self
    }

    /// Sort key — reports are deterministic regardless of analysis order.
    pub fn key(&self) -> (String, usize, &'static str, String) {
        (self.file.clone(), self.line, self.check, self.message.clone())
    }
}

/// Human format, one line per violation plus a summary tail.
pub fn render_human(report: &Report) -> String {
    let mut out = String::new();
    for v in &report.violations {
        out.push_str(&format!("{}:{}: [{}] {}", v.file, v.line, v.check, v.message));
        if let Some(c) = &v.contract {
            out.push_str(&format!("  [{c}]"));
        }
        out.push('\n');
    }
    out.push_str(&format!(
        "{} violation(s), {} contract(s), {} checked use site(s), {} atomic decl(s)\n",
        report.violations.len(),
        report.contracts,
        report.uses,
        report.decls
    ));
    out
}

/// Versioned machine format. Single line, stable field order, sorted
/// violations — byte-for-byte reproducible so it can be diffed across
/// CI runs and pinned by the golden test.
pub fn render_json(report: &Report) -> String {
    let mut out = String::from("{\"version\":1,\"violations\":[");
    for (i, v) in report.violations.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"check\":{},\"file\":{},\"line\":{},\"message\":{}",
            json_str(v.check),
            json_str(&v.file),
            v.line,
            json_str(&v.message)
        ));
        if let Some(c) = &v.contract {
            out.push_str(&format!(",\"contract\":{}", json_str(c)));
        }
        out.push('}');
    }
    out.push_str("]}");
    out
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escaping() {
        assert_eq!(json_str("a\"b\\c\nd"), r#""a\"b\\c\nd""#);
        assert_eq!(json_str("`unsafe`"), "\"`unsafe`\"");
    }

    #[test]
    fn json_shape() {
        let report = Report {
            violations: vec![Violation::new("no-alloc", "src/x.rs", 3, "`vec!` bad".into())
                .with_contract("atomic(x) counter".into())],
            contracts: 1,
            uses: 2,
            decls: 3,
        };
        assert_eq!(
            render_json(&report),
            "{\"version\":1,\"violations\":[{\"check\":\"no-alloc\",\"file\":\"src/x.rs\",\
             \"line\":3,\"message\":\"`vec!` bad\",\"contract\":\"atomic(x) counter\"}]}"
        );
    }
}
