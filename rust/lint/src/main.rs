//! CLI: `cargo run -p blink-lint [-- <root>] [--json]`
//!
//! `<root>` defaults to `rust` (the crate directory, relative to the
//! working directory — from the repo root that is the tree the tier-1
//! gate lints). Exit code 0 = clean, 1 = violations, 2 = usage/io
//! error. `--json` emits the versioned machine report the CI job
//! uploads; the human format goes to stdout otherwise.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root = PathBuf::from("rust");
    let mut json = false;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "--help" | "-h" => {
                println!("usage: blink-lint [ROOT] [--json]");
                println!("lints ROOT/src against ROOT/lint/allow.toml (default ROOT: rust)");
                return ExitCode::SUCCESS;
            }
            a if a.starts_with('-') => {
                eprintln!("blink-lint: unknown flag {a:?} (try --help)");
                return ExitCode::from(2);
            }
            a => root = PathBuf::from(a),
        }
    }
    let report = match blink_lint::run(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("blink-lint: {e}");
            return ExitCode::from(2);
        }
    };
    if json {
        println!("{}", blink_lint::render_json(&report));
    } else {
        print!("{}", blink_lint::render_human(&report));
    }
    if report.clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
