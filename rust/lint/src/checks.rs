//! Tree-wide checks that run after every file is analyzed: use-site
//! ordering conformance against the contracts, undeclared-atomic
//! detection in protocol modules, and the publish/observe pairing
//! cross-check.

use crate::analyze::{is_screaming, UseSite};
use crate::contract::{acquire_class, release_class, Contract, OrdSet};
use crate::diag::Violation;
use std::collections::HashMap;

/// Which contract list governs each ordering argument:
/// * `load` → observe
/// * `store` → publish
/// * `fetch_*` / `swap` → rmw
/// * `compare_exchange[_weak]` / `fetch_update` → rmw (success) and
///   observe (failure — a failed CAS is just a load).
pub fn check_uses(
    contracts: &HashMap<String, Contract>,
    uses: &[UseSite],
    out: &mut Vec<Violation>,
) {
    for u in uses {
        let c = u.recv.as_ref().and_then(|r| contracts.get(r));
        let c = match c {
            Some(c) => c,
            None => {
                // No governing contract. Field-form receivers and
                // SCREAMING statics inside protocol modules must be
                // declared; bare lowercase locals are skipped (no type
                // info without a real frontend).
                let screaming = u.recv.as_deref().map(is_screaming).unwrap_or(false);
                if u.protocol && (u.field || screaming) {
                    out.push(Violation::new(
                        "atomic-undeclared",
                        &u.file,
                        u.line,
                        format!(
                            "use of undeclared atomic `{}` ({}) in protocol module",
                            u.recv.as_deref().unwrap_or("?"),
                            u.method
                        ),
                    ));
                }
                continue;
            }
        };
        let recv = u.recv.as_deref().unwrap_or("?");
        let bad = |which: &str, ord: &str, allowed: OrdSet, out: &mut Vec<Violation>| {
            out.push(
                Violation::new(
                    "atomic-ordering",
                    &u.file,
                    u.line,
                    format!(
                        "`{recv}.{}` uses Ordering::{ord} but the contract allows {which}={allowed}",
                        u.method
                    ),
                )
                .with_contract(c.display()),
            );
        };
        let o = &u.ords;
        match u.method.as_str() {
            "load" => {
                if !c.observe.contains(&o[0]) {
                    bad("observe", &o[0], c.observe, out);
                }
            }
            "store" => {
                if !c.publish.contains(&o[0]) {
                    bad("publish", &o[0], c.publish, out);
                }
            }
            "compare_exchange" | "compare_exchange_weak" | "fetch_update" => {
                if !c.rmw.contains(&o[0]) {
                    bad("rmw", &o[0], c.rmw, out);
                }
                if o.len() > 1 && !c.observe.contains(&o[1]) {
                    bad("observe", &o[1], c.observe, out);
                }
            }
            _ => {
                if !c.rmw.contains(&o[0]) {
                    bad("rmw", &o[0], c.rmw, out);
                }
            }
        }
    }
}

/// Pairing cross-check: a contract that *mandates* release publishes
/// (publish set nonempty and wholly within {Release, AcqRel, SeqCst})
/// with actual writers in the tree must have at least one acquire-side
/// observer somewhere — otherwise the Release is decoration and the
/// contract is lying about the protocol. Symmetrically for mandated
/// acquire observes with actual readers. `flag` contracts opt out:
/// their whole point is that Relaxed is also legal on both sides.
pub fn crosscheck(
    contracts: &HashMap<String, Contract>,
    uses: &[UseSite],
    out: &mut Vec<Violation>,
) {
    let mut by_name: HashMap<&str, Vec<&UseSite>> = HashMap::new();
    for u in uses {
        if let Some(r) = u.recv.as_deref() {
            by_name.entry(r).or_default().push(u);
        }
    }
    let mut names: Vec<&String> = contracts.keys().collect();
    names.sort();
    for name in names {
        let c = &contracts[name];
        if !c.crosscheck {
            continue;
        }
        let us = by_name.get(name.as_str()).map(|v| v.as_slice()).unwrap_or(&[]);
        let has_writes = us.iter().any(|u| u.method != "load");
        let has_reads = us.iter().any(|u| u.method != "store");
        if !c.publish.is_empty() && c.publish.is_subset(release_class()) && has_writes {
            let observed = us.iter().any(|u| {
                let m = u.method.as_str();
                (m == "load" && acquire_class().contains(&u.ords[0]))
                    || (m != "load" && m != "store" && acquire_class().contains(&u.ords[0]))
                    || (matches!(m, "compare_exchange" | "compare_exchange_weak" | "fetch_update")
                        && u.ords.len() > 1
                        && acquire_class().contains(&u.ords[1]))
            });
            if !observed {
                out.push(
                    Violation::new(
                        "atomic-unpaired",
                        &c.file,
                        c.line,
                        format!(
                            "atomic({name}) mandates release publishes but no acquire-side \
                             observer exists in the tree"
                        ),
                    )
                    .with_contract(c.display()),
                );
            }
        }
        if !c.observe.is_empty() && c.observe.is_subset(acquire_class()) && has_reads {
            let published = us.iter().any(|u| {
                let m = u.method.as_str();
                (m == "store" && release_class().contains(&u.ords[0]))
                    || (m != "load" && m != "store" && release_class().contains(&u.ords[0]))
            });
            if !published {
                out.push(
                    Violation::new(
                        "atomic-unpaired",
                        &c.file,
                        c.line,
                        format!(
                            "atomic({name}) mandates acquire observes but no release-side \
                             publisher exists in the tree"
                        ),
                    )
                    .with_contract(c.display()),
                );
            }
        }
    }
}
