//! The contract annotation grammar (DESIGN.md §10):
//!
//! ```text
//! // lint: atomic(NAME) SPEC [# prose]
//! SPEC  := counter | plane | flag | KV+
//! KV    := publish=LIST | observe=LIST | rmw=LIST
//! LIST  := ORD("|"ORD)*      ORD := Relaxed|Acquire|Release|AcqRel|SeqCst
//! ```
//!
//! `publish` governs `store` orderings, `observe` governs `load` (and
//! the failure ordering of compare-exchange / fetch_update), `rmw`
//! governs read-modify-write success orderings. The shorthands encode
//! the three recurring protocol roles:
//!
//! * `counter` — statistics only, every op Relaxed; never used to
//!   order other memory.
//! * `plane` — a data-plane cell (Relaxed load/store only) whose
//!   visibility is guaranteed by a *different* field's release edge.
//! * `flag` — a shutdown/drain bit: Release publish and Acquire
//!   observe permitted but Relaxed also legal (spin loops that only
//!   need eventual visibility). Exempt from pairing cross-checks.

use crate::diag::Violation;
use std::fmt;

pub const ORDERINGS: [&str; 5] = ["AcqRel", "Acquire", "Relaxed", "Release", "SeqCst"];

/// Set of memory orderings, packed; display is alphabetical to match
/// the report format.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct OrdSet(u8);

impl OrdSet {
    pub const EMPTY: OrdSet = OrdSet(0);

    pub fn bit(name: &str) -> Option<u8> {
        ORDERINGS.iter().position(|o| *o == name).map(|i| 1 << i)
    }

    pub fn of(names: &[&str]) -> OrdSet {
        let mut s = OrdSet(0);
        for n in names {
            s.0 |= OrdSet::bit(n).expect("known ordering");
        }
        s
    }

    pub fn insert(&mut self, name: &str) -> bool {
        match OrdSet::bit(name) {
            Some(b) => {
                self.0 |= b;
                true
            }
            None => false,
        }
    }

    pub fn contains(&self, name: &str) -> bool {
        OrdSet::bit(name).map(|b| self.0 & b != 0).unwrap_or(false)
    }

    pub fn is_empty(&self) -> bool {
        self.0 == 0
    }

    pub fn is_subset(&self, other: OrdSet) -> bool {
        self.0 & !other.0 == 0
    }
}

impl fmt::Display for OrdSet {
    /// `Acquire|SeqCst`, alphabetical; `(none)` for the empty set.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_empty() {
            return write!(f, "(none)");
        }
        let mut first = true;
        for (i, name) in ORDERINGS.iter().enumerate() {
            if self.0 & (1 << i) != 0 {
                if !first {
                    write!(f, "|")?;
                }
                write!(f, "{name}")?;
                first = false;
            }
        }
        Ok(())
    }
}

/// Stores must synchronize-with something to be a release edge.
pub fn release_class() -> OrdSet {
    OrdSet::of(&["Release", "AcqRel", "SeqCst"])
}

/// Loads that complete a synchronizes-with edge.
pub fn acquire_class() -> OrdSet {
    OrdSet::of(&["Acquire", "AcqRel", "SeqCst"])
}

#[derive(Clone, Debug)]
pub struct Contract {
    pub name: String,
    /// Original spec text (after the name, before any `#` prose) — kept
    /// verbatim for diagnostics.
    pub spec: String,
    pub publish: OrdSet,
    pub observe: OrdSet,
    pub rmw: OrdSet,
    /// Whether this contract participates in the release/acquire
    /// pairing cross-check (`flag` opts out).
    pub crosscheck: bool,
    pub file: String,
    pub line: usize,
}

impl Contract {
    /// Two contracts for the same name are compatible iff their
    /// *resolved* sets match — `publish=Relaxed observe=Relaxed` and a
    /// differently-ordered spelling of the same sets merge cleanly.
    pub fn same_resolved(&self, other: &Contract) -> bool {
        self.publish == other.publish
            && self.observe == other.observe
            && self.rmw == other.rmw
            && self.crosscheck == other.crosscheck
    }

    pub fn display(&self) -> String {
        format!("atomic({}) {}", self.name, self.spec)
    }
}

fn shorthand(spec: &str) -> Option<(OrdSet, OrdSet, OrdSet, bool)> {
    match spec {
        "counter" => {
            let r = OrdSet::of(&["Relaxed"]);
            Some((r, r, r, true))
        }
        "plane" => {
            let r = OrdSet::of(&["Relaxed"]);
            Some((r, r, OrdSet::EMPTY, true))
        }
        "flag" => Some((
            OrdSet::of(&["Relaxed", "Release"]),
            OrdSet::of(&["Relaxed", "Acquire"]),
            OrdSet::EMPTY,
            false,
        )),
        _ => None,
    }
}

fn is_ident(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Parse the directive body after `lint: ` when it starts with
/// `atomic(`. Returns `None` (with a `contract-syntax` violation
/// pushed) on any malformation — a half-parsed contract must never
/// silently weaken enforcement.
pub fn parse_contract(
    directive: &str,
    file: &str,
    line: usize,
    out: &mut Vec<Violation>,
) -> Option<Contract> {
    let bad = |out: &mut Vec<Violation>, msg: String| {
        out.push(Violation::new("contract-syntax", file, line, msg));
    };
    let rest = match directive.strip_prefix("atomic(") {
        Some(r) => r,
        None => {
            bad(out, format!("unparseable atomic contract: {directive:?}"));
            return None;
        }
    };
    let close = match rest.find(')') {
        Some(c) => c,
        None => {
            bad(out, format!("unparseable atomic contract: {directive:?}"));
            return None;
        }
    };
    let name = &rest[..close];
    if !is_ident(name) {
        bad(out, format!("unparseable atomic contract: {directive:?}"));
        return None;
    }
    // Strip trailing `# prose`.
    let spec_full = rest[close + 1..].trim();
    let spec = spec_full.split('#').next().unwrap_or("").trim().to_string();

    if let Some((publish, observe, rmw, crosscheck)) = shorthand(&spec) {
        return Some(Contract {
            name: name.to_string(),
            spec,
            publish,
            observe,
            rmw,
            crosscheck,
            file: file.to_string(),
            line,
        });
    }
    if spec.is_empty() {
        bad(out, format!("empty contract for atomic({name})"));
        return None;
    }
    let mut c = Contract {
        name: name.to_string(),
        spec: spec.clone(),
        publish: OrdSet::EMPTY,
        observe: OrdSet::EMPTY,
        rmw: OrdSet::EMPTY,
        crosscheck: true,
        file: file.to_string(),
        line,
    };
    for kv in spec.split_whitespace() {
        let (k, v) = match kv.split_once('=') {
            Some(p) => p,
            None => {
                bad(out, format!("bad contract token {kv:?} for atomic({name})"));
                return None;
            }
        };
        let set = match k {
            "publish" => &mut c.publish,
            "observe" => &mut c.observe,
            "rmw" => &mut c.rmw,
            _ => {
                bad(out, format!("unknown contract key {k:?} for atomic({name})"));
                return None;
            }
        };
        if v.is_empty() || !v.split('|').all(|o| set.insert(o)) {
            bad(out, format!("bad ordering list {v:?} for atomic({name})"));
            return None;
        }
    }
    Some(c)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_ok(d: &str) -> Contract {
        let mut out = vec![];
        let c = parse_contract(d, "f.rs", 1, &mut out);
        assert!(out.is_empty(), "{out:?}");
        c.unwrap()
    }

    #[test]
    fn shorthands_resolve() {
        let c = parse_ok("atomic(hits) counter");
        assert_eq!(c.publish, OrdSet::of(&["Relaxed"]));
        assert_eq!(c.rmw, OrdSet::of(&["Relaxed"]));
        assert!(c.crosscheck);
        let f = parse_ok("atomic(stop) flag");
        assert!(!f.crosscheck);
        assert!(f.publish.contains("Release") && f.publish.contains("Relaxed"));
        let p = parse_ok("atomic(row) plane");
        assert!(p.rmw.is_empty());
    }

    #[test]
    fn explicit_lists_and_prose() {
        let c = parse_ok("atomic(state) publish=Release observe=Acquire|Relaxed rmw=AcqRel # x");
        assert_eq!(c.publish, OrdSet::of(&["Release"]));
        assert_eq!(c.observe, OrdSet::of(&["Acquire", "Relaxed"]));
        assert_eq!(c.rmw, OrdSet::of(&["AcqRel"]));
        assert_eq!(c.spec, "publish=Release observe=Acquire|Relaxed rmw=AcqRel");
    }

    #[test]
    fn resolved_equality_ignores_spelling() {
        let a = parse_ok("atomic(x) publish=Relaxed observe=Relaxed rmw=Relaxed");
        let b = parse_ok("atomic(x) counter");
        assert!(a.same_resolved(&b));
    }

    #[test]
    fn malformed_specs_are_rejected() {
        for bad in [
            "atomic(x)",
            "atomic(x) bogus",
            "atomic(x) publish=Released",
            "atomic(x) lock=Relaxed",
            "atomic(x) publish=",
            "atomic(2x) counter",
            "atomic(x counter",
        ] {
            let mut out = vec![];
            assert!(parse_contract(bad, "f.rs", 1, &mut out).is_none(), "{bad}");
            assert_eq!(out.len(), 1, "{bad}");
            assert_eq!(out[0].check, "contract-syntax");
        }
    }

    #[test]
    fn ordset_display_sorted() {
        assert_eq!(OrdSet::of(&["SeqCst", "Acquire"]).to_string(), "Acquire|SeqCst");
        assert_eq!(OrdSet::EMPTY.to_string(), "(none)");
    }
}
