//! A deliberately small Rust lexer — just enough token structure for the
//! protocol checks. It understands exactly the constructs that would
//! otherwise confuse a text scan: line/block comments (kept, because the
//! contract annotations live in them), string/char literals (blanked,
//! so `"Vec::new"` inside a message never trips a deny-list), raw
//! strings with `#` fences, and lifetimes (dropped, so `'a` is not a
//! char literal). Everything else degrades to single-character `Punct`
//! tokens; the analyses that need grouping re-match delimiters
//! themselves.

/// Token classes. `Str` tokens keep their position but drop their text —
/// they act as opaque spacers so neighbor-pattern matches (`.` `load`
/// `(`) can never be satisfied by literal contents.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Num,
    Punct,
    Str,
    Comment,
}

#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: usize,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Tokenize `src`. Lines are 1-based. Comments are yielded with their
/// full text (including the `//` / `/*` sigils) so the annotation pass
/// can strip them itself.
pub fn tokenize(src: &str) -> Vec<Tok> {
    let s: Vec<char> = src.chars().collect();
    let n = s.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    let at = |i: usize, a: char, b: char| -> bool { i + 1 < n && s[i] == a && s[i + 1] == b };
    while i < n {
        let c = s[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        if at(i, '/', '/') {
            let mut j = i;
            while j < n && s[j] != '\n' {
                j += 1;
            }
            toks.push(Tok { kind: Kind::Comment, text: s[i..j].iter().collect(), line });
            i = j;
            continue;
        }
        if at(i, '/', '*') {
            let mut j = i + 2;
            while j < n && !at(j, '*', '/') {
                j += 1;
            }
            let j = if j < n { j + 2 } else { n };
            let text: String = s[i..j].iter().collect();
            toks.push(Tok { kind: Kind::Comment, text: text.clone(), line });
            line += text.matches('\n').count();
            i = j;
            continue;
        }
        // Raw string: r"..." or r#..#"..."#..# (any fence width).
        if c == 'r' && i + 1 < n && (s[i + 1] == '"' || s[i + 1] == '#') {
            let mut j = i + 1;
            let mut hashes = 0usize;
            while j < n && s[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && s[j] == '"' {
                // Find the closing `"###...` fence.
                let mut k = j + 1;
                let end;
                loop {
                    if k >= n {
                        end = n;
                        break;
                    }
                    if s[k] == '"' {
                        let mut h = 0usize;
                        while k + 1 + h < n && s[k + 1 + h] == '#' && h < hashes {
                            h += 1;
                        }
                        if h == hashes {
                            end = k + 1 + hashes;
                            break;
                        }
                    }
                    k += 1;
                }
                line += s[i..end].iter().filter(|c| **c == '\n').count();
                toks.push(Tok { kind: Kind::Str, text: String::new(), line });
                i = end;
                continue;
            }
            // `r` followed by `#` but no quote: fall through as ident.
        }
        if c == '"' {
            let mut j = i + 1;
            while j < n {
                if s[j] == '\\' {
                    j += 2;
                    continue;
                }
                if s[j] == '"' {
                    j += 1;
                    break;
                }
                j += 1;
            }
            let j = j.min(n);
            line += s[i..j].iter().filter(|c| **c == '\n').count();
            toks.push(Tok { kind: Kind::Str, text: String::new(), line });
            i = j;
            continue;
        }
        if c == '\'' {
            // Char literal vs lifetime.
            if i + 1 < n && s[i + 1] == '\\' {
                let mut j = i + 2;
                while j < n && s[j] != '\'' {
                    j += 1;
                }
                toks.push(Tok { kind: Kind::Str, text: String::new(), line });
                i = if j < n { j + 1 } else { n };
                continue;
            }
            if i + 2 < n && s[i + 2] == '\'' {
                toks.push(Tok { kind: Kind::Str, text: String::new(), line });
                i += 3;
                continue;
            }
            i += 1; // lifetime tick — the name lexes as a plain ident
            continue;
        }
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_cont(s[j]) {
                j += 1;
            }
            toks.push(Tok { kind: Kind::Ident, text: s[i..j].iter().collect(), line });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n && (is_ident_cont(s[j]) || s[j] == '.') {
                // Stop before `..` so ranges like `0..len` keep their dots.
                if s[j] == '.' && j + 1 < n && s[j + 1] == '.' {
                    break;
                }
                j += 1;
            }
            toks.push(Tok { kind: Kind::Num, text: s[i..j].iter().collect(), line });
            i = j;
            continue;
        }
        toks.push(Tok { kind: Kind::Punct, text: c.to_string(), line });
        i += 1;
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<(Kind, String)> {
        tokenize(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn strings_are_blanked() {
        let t = texts(r#"let x = "Vec::new()"; y.load(o)"#);
        assert!(t.iter().any(|(k, _)| *k == Kind::Str));
        assert!(!t.iter().any(|(_, s)| s.contains("Vec")));
        assert!(t.iter().any(|(_, s)| s == "load"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let t = texts("fn f<'a>(x: &'a str) -> &'a str { x }");
        // The lifetime name lexes as a bare ident, not a string.
        assert!(t.iter().filter(|(_, s)| s == "a").count() >= 3);
        assert!(!t.iter().any(|(k, _)| *k == Kind::Str));
    }

    #[test]
    fn char_literals_and_escapes() {
        let t = texts(r"let c = '\n'; let d = 'x';");
        assert_eq!(t.iter().filter(|(k, _)| *k == Kind::Str).count(), 2);
    }

    #[test]
    fn raw_strings_with_fences() {
        let t = texts(r###"let s = r#"a "quoted" b"#; z.store(1, o)"###);
        assert!(t.iter().any(|(_, s)| s == "store"));
        assert!(!t.iter().any(|(_, s)| s == "quoted"));
    }

    #[test]
    fn comments_keep_text_and_lines() {
        let t = tokenize("// lint: atomic(x) counter\nlet y = 1;\n/* block */ z");
        assert_eq!(t[0].kind, Kind::Comment);
        assert!(t[0].text.contains("atomic(x)"));
        assert_eq!(t[0].line, 1);
        let z = t.iter().find(|t| t.text == "z").unwrap();
        assert_eq!(z.line, 3);
    }

    #[test]
    fn range_dots_stay_punct() {
        let t = texts("for i in 0..n {}");
        assert!(t.iter().any(|(_, s)| s == "0"));
        assert_eq!(t.iter().filter(|(_, s)| s == ".").count(), 2);
    }
}
