//! blink-lint — the repo-native static analysis pass that enforces the
//! lock-free protocol contracts (DESIGN.md §10 "Static invariants").
//!
//! The serving stack's correctness rests on a handful of cross-thread
//! protocols (ring-slot state machine, launch-arena epoch handoff,
//! overload-gate slab, stats planes). The compiler cannot check that a
//! `store(Release)` here is matched by a `load(Acquire)` there, or that
//! the steady-state decode loop stays allocation-free; this pass can,
//! because the repo writes those obligations down next to the code:
//!
//! * `// lint: atomic(name) spec` — an ordering contract on an atomic
//!   field or static (see [`contract`] for the grammar). Every use of
//!   that atomic, tree-wide, must conform; contracts mandating release
//!   publishes must have acquire observers and vice versa; atomics in
//!   protocol modules must be declared at all.
//! * `// lint: no_alloc no_panic` — tags the next `fn` as a hot-path
//!   region where allocation (and/or panicking) calls are denied.
//! * `// SAFETY:` — required directly above every `unsafe`.
//! * `rust/lint/allow.toml` — narrowly scoped, reasoned suppressions.
//!
//! Dependency-free by design: a hand-rolled lexer ([`lex`]) instead of
//! syn, a hand-parsed allowlist instead of a TOML crate. The analysis
//! is resolutely syntactic — no type information — and the known holes
//! are documented where they live (bare-local receivers in
//! [`analyze::UseSite::recv`]).

pub mod allow;
pub mod analyze;
pub mod checks;
pub mod contract;
pub mod diag;
pub mod lex;

use analyze::{analyze_file, merge_contracts};
use diag::Violation;
use std::collections::HashMap;
use std::io;
use std::path::{Path, PathBuf};

pub use diag::{render_human, render_json};

#[derive(Debug, Default)]
pub struct Report {
    /// Sorted by (file, line, check, message); post-allowlist.
    pub violations: Vec<Violation>,
    pub contracts: usize,
    pub uses: usize,
    pub decls: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Run the full pass over `<root>/src`, applying `<root>/lint/allow.toml`
/// when present. `root` is the crate directory (the repo invokes this
/// with `rust/`).
pub fn run(root: &Path) -> io::Result<Report> {
    let src_root = root.join("src");
    let mut files: Vec<PathBuf> = Vec::new();
    collect_rs(&src_root, &mut files)?;
    // Sort by the relative path string so the walk order (and with it
    // every first-wins rule: contract registration, duplicate merge) is
    // stable across platforms.
    files.sort();

    let mut out: Vec<Violation> = Vec::new();
    let mut contracts = HashMap::new();
    let mut uses = Vec::new();
    let mut decls = 0usize;
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(path)?;
        let fa = analyze_file(&src, &rel, &mut out);
        decls += fa.decls.len();
        uses.extend(fa.uses);
        merge_contracts(&mut contracts, fa.contracts, &rel, &mut out);
    }
    checks::check_uses(&contracts, &uses, &mut out);
    checks::crosscheck(&contracts, &uses, &mut out);

    let mut entries = allow::parse_allowlist(&root.join("lint").join("allow.toml"), &mut out);
    let mut out = allow::apply_allowlist(&mut entries, out, root);
    out.sort_by_key(|v| v.key());

    Ok(Report { violations: out, contracts: contracts.len(), uses: uses.len(), decls })
}

fn collect_rs(dir: &Path, files: &mut Vec<PathBuf>) -> io::Result<()> {
    if !dir.is_dir() {
        return Err(io::Error::new(
            io::ErrorKind::NotFound,
            format!("lint root has no src/ directory: {}", dir.display()),
        ));
    }
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(dir)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, files)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            files.push(path);
        }
    }
    Ok(())
}
