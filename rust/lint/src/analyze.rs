//! Per-file analysis: two passes over the token stream.
//!
//! Pass 0 reads comments only — contract annotations, `no_alloc` /
//! `no_panic` region tags, and `SAFETY:` markers.
//!
//! Pass 1 walks the code tokens with a brace stack, tracking struct
//! bodies (for atomic field declarations), tagged-fn regions (for the
//! deny-lists), `unsafe` keywords (for SAFETY coverage), and every
//! atomic-method call that names an `Ordering` (the use sites the
//! contract checks consume).

use crate::contract::{parse_contract, Contract};
use crate::diag::Violation;
use crate::lex::{tokenize, Kind, Tok};
use std::collections::HashMap;

pub const ATOMIC_METHODS: [&str; 14] = [
    "load",
    "store",
    "swap",
    "fetch_add",
    "fetch_sub",
    "fetch_and",
    "fetch_nand",
    "fetch_or",
    "fetch_xor",
    "fetch_max",
    "fetch_min",
    "fetch_update",
    "compare_exchange",
    "compare_exchange_weak",
];

const ATOMIC_TYPES: [&str; 13] = [
    "AtomicU8",
    "AtomicU16",
    "AtomicU32",
    "AtomicU64",
    "AtomicUsize",
    "AtomicI8",
    "AtomicI16",
    "AtomicI32",
    "AtomicI64",
    "AtomicIsize",
    "AtomicBool",
    "AtomicPtr",
];

/// Modules that host the lock-free protocols (paper §4): every atomic
/// they declare or touch must carry an explicit contract. Matched as
/// path fragments under the lint root.
pub const PROTOCOL_MODULES: [&str; 6] =
    ["ringbuf/", "gpu/arena.rs", "frontend/overload.rs", "gpu/stats.rs", "rdma/", "devsim/"];

const NO_ALLOC_MACROS: [&str; 6] = ["vec", "format", "println", "eprintln", "print", "eprint"];
const NO_ALLOC_PATHS: [(&str, &str); 4] =
    [("Box", "new"), ("Vec", "new"), ("String", "new"), ("String", "from")];
const NO_ALLOC_METHODS: [&str; 5] = ["to_string", "to_owned", "to_vec", "collect", "lock"];
const NO_PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];
const NO_PANIC_METHODS: [&str; 2] = ["unwrap", "expect"];

/// One recognized atomic operation with explicit orderings.
#[derive(Clone, Debug)]
pub struct UseSite {
    pub file: String,
    pub line: usize,
    /// Resolved receiver name, if the walk-back found one. Bare
    /// lowercase locals resolve but are skipped by the contract lookup
    /// (documented hole: a local binding shadows nothing we can see
    /// without type information).
    pub recv: Option<String>,
    /// True when the receiver was field-form (`something.name.load`),
    /// i.e. preceded by a `.`.
    pub field: bool,
    pub method: String,
    /// Ordering idents in argument order (`compare_exchange` has two).
    pub ords: Vec<String>,
    pub protocol: bool,
}

#[derive(Clone, Debug)]
pub struct Decl {
    pub file: String,
    #[allow(dead_code)]
    pub line: usize,
    pub name: String,
    #[allow(dead_code)]
    pub protocol: bool,
}

pub fn is_protocol(rel: &str) -> bool {
    PROTOCOL_MODULES.iter().any(|p| {
        rel.starts_with(&format!("src/{p}")) || rel.contains(&format!("/{p}")) || rel.ends_with(p)
    })
}

/// A SCREAMING_CASE receiver is a static, not a local — at least one
/// cased char and no lowercase ones.
pub fn is_screaming(s: &str) -> bool {
    s.chars().any(|c| c.is_uppercase()) && !s.chars().any(|c| c.is_lowercase())
}

struct TagRegion {
    tags: (bool, bool), // (no_alloc, no_panic)
    depth: usize,       // brace depth at which the fn body opened
}

pub struct FileAnalysis {
    pub contracts: HashMap<String, Contract>,
    pub uses: Vec<UseSite>,
    pub decls: Vec<Decl>,
}

pub fn analyze_file(src: &str, rel: &str, out: &mut Vec<Violation>) -> FileAnalysis {
    let raw_lines: Vec<&str> = src.split('\n').collect();
    let toks = tokenize(src);
    let protocol = is_protocol(rel);

    // --- pass 0: comments → contracts, region tags, SAFETY lines.
    let mut file_contracts: HashMap<String, Contract> = HashMap::new();
    let mut tags: Vec<(usize, (bool, bool))> = Vec::new();
    let mut safety_lines: Vec<usize> = Vec::new();
    for t in &toks {
        if t.kind != Kind::Comment {
            continue;
        }
        let body = t.text.trim_start_matches('/').trim_start_matches('*').trim();
        if body.starts_with("SAFETY:") {
            safety_lines.push(t.line);
            continue;
        }
        let directive = match body.strip_prefix("lint:") {
            Some(d) => d.trim(),
            None => continue,
        };
        if directive.starts_with("atomic(") {
            let c = match parse_contract(directive, rel, t.line, out) {
                Some(c) => c,
                None => continue,
            };
            if let Some(prev) = file_contracts.get(&c.name) {
                if !prev.same_resolved(&c) {
                    out.push(Violation::new(
                        "atomic-conflict",
                        rel,
                        t.line,
                        format!(
                            "contract for atomic({}) conflicts with {}:{}",
                            c.name, prev.file, prev.line
                        ),
                    ));
                }
                continue;
            }
            file_contracts.insert(c.name.clone(), c);
        } else {
            let words: Vec<&str> =
                directive.split('#').next().unwrap_or("").split_whitespace().collect();
            if !words.is_empty() && words.iter().all(|w| *w == "no_alloc" || *w == "no_panic") {
                tags.push((
                    t.line,
                    (words.contains(&"no_alloc"), words.contains(&"no_panic")),
                ));
            } else {
                out.push(Violation::new(
                    "contract-syntax",
                    rel,
                    t.line,
                    format!("unknown lint directive: {directive:?}"),
                ));
            }
        }
    }
    tags.sort_by_key(|(l, _)| *l);

    // --- pass 1: code tokens (comments removed; strings kept as spacers).
    let code: Vec<&Tok> = toks.iter().filter(|t| t.kind != Kind::Comment).collect();
    let blank = Tok { kind: Kind::Punct, text: String::new(), line: 0 };

    let mut stack: Vec<bool> = Vec::new(); // true = struct body
    let mut pending_struct = false;
    let mut tag_idx = 0usize;
    let mut pending_tag: Option<(bool, bool)> = None;
    let mut pending_fn = false;
    let mut region_stack: Vec<TagRegion> = Vec::new();

    let mut uses: Vec<UseSite> = Vec::new();
    let mut decls: Vec<Decl> = Vec::new();

    for (idx, tok) in code.iter().enumerate() {
        let (kind, text, line) = (tok.kind, tok.text.as_str(), tok.line);

        // A tag annotates the next `fn` after its comment line.
        while tag_idx < tags.len() && tags[tag_idx].0 < line {
            pending_tag = Some(tags[tag_idx].1);
            pending_fn = false;
            tag_idx += 1;
        }
        if kind == Kind::Ident && text == "fn" && pending_tag.is_some() {
            pending_fn = true;
        }
        if kind == Kind::Ident && text == "struct" {
            pending_struct = true;
        }
        if kind == Kind::Punct && text == ";" && pending_struct {
            pending_struct = false; // unit/tuple struct
        }
        if kind == Kind::Punct && text == "{" {
            if pending_fn {
                region_stack
                    .push(TagRegion { tags: pending_tag.take().unwrap(), depth: stack.len() });
                pending_fn = false;
            }
            stack.push(pending_struct);
            pending_struct = false;
            continue;
        }
        if kind == Kind::Punct && text == "}" {
            if !stack.is_empty() {
                stack.pop();
                if region_stack.last().map(|r| r.depth == stack.len()).unwrap_or(false) {
                    region_stack.pop();
                }
            }
            continue;
        }

        let mut no_alloc = false;
        let mut no_panic = false;
        for r in &region_stack {
            no_alloc |= r.tags.0;
            no_panic |= r.tags.1;
        }

        // SAFETY coverage: an `unsafe` keyword is covered by a SAFETY:
        // comment on the same line or in the contiguous run of
        // comment/attribute/blank lines directly above.
        if kind == Kind::Ident && text == "unsafe" {
            let mut ok = safety_lines.contains(&line);
            let mut ln = line.saturating_sub(1);
            while !ok && ln >= 1 {
                let raw = raw_lines.get(ln - 1).map(|s| s.trim()).unwrap_or("");
                if raw.starts_with("//")
                    || raw.starts_with("#[")
                    || raw.starts_with('*')
                    || raw.starts_with("/*")
                    || raw.is_empty()
                {
                    if safety_lines.contains(&ln) || raw.contains("SAFETY:") {
                        ok = true;
                    }
                    ln -= 1;
                } else {
                    break;
                }
            }
            if !ok {
                out.push(Violation::new(
                    "safety-comment",
                    rel,
                    line,
                    "`unsafe` without a `// SAFETY:` comment directly above".to_string(),
                ));
            }
        }

        // Deny-lists inside tagged regions.
        if no_alloc || no_panic {
            let nxt = code.get(idx + 1).copied().unwrap_or(&blank);
            let nx2 = code.get(idx + 2).copied().unwrap_or(&blank);
            let prev = if idx > 0 { code[idx - 1] } else { &blank };
            if no_alloc && kind == Kind::Ident {
                if NO_ALLOC_MACROS.contains(&text) && nxt.text == "!" {
                    out.push(Violation::new(
                        "no-alloc",
                        rel,
                        line,
                        format!("`{text}!` in a no_alloc region"),
                    ));
                }
                if nxt.text == ":" && nx2.text == ":" {
                    if let Some(seg) = code.get(idx + 3) {
                        if NO_ALLOC_PATHS.contains(&(text, seg.text.as_str())) {
                            out.push(Violation::new(
                                "no-alloc",
                                rel,
                                line,
                                format!("`{}::{}` in a no_alloc region", text, seg.text),
                            ));
                        }
                    }
                }
                if NO_ALLOC_METHODS.contains(&text) && prev.text == "." && nxt.text == "(" {
                    out.push(Violation::new(
                        "no-alloc",
                        rel,
                        line,
                        format!("`.{text}()` in a no_alloc region"),
                    ));
                }
            }
            if no_panic && kind == Kind::Ident {
                if NO_PANIC_MACROS.contains(&text) && nxt.text == "!" {
                    out.push(Violation::new(
                        "no-panic",
                        rel,
                        line,
                        format!("`{text}!` in a no_panic region"),
                    ));
                }
                if NO_PANIC_METHODS.contains(&text) && prev.text == "." && nxt.text == "(" {
                    out.push(Violation::new(
                        "no-panic",
                        rel,
                        line,
                        format!("`.{text}()` in a no_panic region"),
                    ));
                }
            }
        }

        // Atomic field declarations inside struct bodies.
        if stack.last().copied().unwrap_or(false)
            && kind == Kind::Ident
            && code.get(idx + 1).map(|t| t.text == ":").unwrap_or(false)
        {
            let mut j = idx + 2;
            let mut depth = 0i32;
            let mut has_atomic = false;
            while j < code.len() {
                let t2 = code[j].text.as_str();
                if code[j].kind == Kind::Punct && matches!(t2, "(" | "[" | "{" | "<") {
                    depth += 1;
                } else if code[j].kind == Kind::Punct && matches!(t2, ")" | "]" | "}" | ">") {
                    if t2 == "}" && depth == 0 {
                        break;
                    }
                    depth -= 1;
                } else if code[j].kind == Kind::Punct && t2 == "," && depth <= 0 {
                    break;
                } else if code[j].kind == Kind::Ident && ATOMIC_TYPES.contains(&t2) {
                    has_atomic = true;
                }
                j += 1;
            }
            if has_atomic {
                decls.push(Decl { file: rel.to_string(), line, name: text.to_string(), protocol });
                if protocol && !file_contracts.contains_key(text) {
                    out.push(Violation::new(
                        "atomic-undeclared",
                        rel,
                        line,
                        format!(
                            "atomic field `{text}` in protocol module has no \
                             `// lint: atomic({text}) ...` contract"
                        ),
                    ));
                }
            }
        }

        // Static atomics.
        if kind == Kind::Ident && text == "static" {
            let mut j = idx + 1;
            if code.get(j).map(|t| t.text == "mut").unwrap_or(false) {
                j += 1;
            }
            let named = code.get(j).map(|t| t.kind == Kind::Ident).unwrap_or(false)
                && code.get(j + 1).map(|t| t.text == ":").unwrap_or(false);
            if named {
                let name_tok = code[j];
                let mut k = j + 2;
                let mut has_atomic = false;
                while k < code.len() && code[k].text != "=" && code[k].text != ";" {
                    if code[k].kind == Kind::Ident && ATOMIC_TYPES.contains(&code[k].text.as_str())
                    {
                        has_atomic = true;
                    }
                    k += 1;
                }
                if has_atomic {
                    decls.push(Decl {
                        file: rel.to_string(),
                        line: name_tok.line,
                        name: name_tok.text.clone(),
                        protocol,
                    });
                    if protocol && !file_contracts.contains_key(&name_tok.text) {
                        out.push(Violation::new(
                            "atomic-undeclared",
                            rel,
                            name_tok.line,
                            format!(
                                "atomic static `{}` in protocol module has no contract",
                                name_tok.text
                            ),
                        ));
                    }
                }
            }
        }

        // Atomic use sites: RECV `.` METHOD `(` ... Ordering::X ... `)`.
        // Only calls that name at least one `Ordering` count — this is
        // what separates `slot.load(...)` on an atomic from `Vec::load`
        // lookalikes and `cmp::Ordering` matches.
        if kind == Kind::Ident
            && ATOMIC_METHODS.contains(&text)
            && idx >= 2
            && code[idx - 1].text == "."
            && code.get(idx + 1).map(|t| t.text == "(").unwrap_or(false)
        {
            let r = idx - 2;
            let mut recv: Option<String> = None;
            let mut field = false;
            if code[r].kind == Kind::Ident {
                recv = Some(code[r].text.clone());
                field = r >= 1 && code[r - 1].text == ".";
            } else if code[r].text == ")" || code[r].text == "]" {
                let close = code[r].text.clone();
                let opener = if close == ")" { "(" } else { "[" };
                let mut depth = 0i32;
                let mut k = r as isize;
                while k >= 0 {
                    let t = code[k as usize].text.as_str();
                    if t == close {
                        depth += 1;
                    } else if t == opener {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    k -= 1;
                }
                if k >= 1 && code[(k - 1) as usize].kind == Kind::Ident {
                    recv = Some(code[(k - 1) as usize].text.clone());
                    field = k >= 2 && code[(k - 2) as usize].text == ".";
                }
            }
            // Collect `Ordering::X` idents inside the call parens.
            let mut j = idx + 1;
            let mut depth = 0i32;
            let mut ords: Vec<String> = Vec::new();
            while j < code.len() {
                let t2 = code[j].text.as_str();
                if t2 == "(" {
                    depth += 1;
                } else if t2 == ")" {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if code[j].kind == Kind::Ident
                    && t2 == "Ordering"
                    && code.get(j + 1).map(|t| t.text == ":").unwrap_or(false)
                    && code.get(j + 2).map(|t| t.text == ":").unwrap_or(false)
                    && code.get(j + 3).map(|t| t.kind == Kind::Ident).unwrap_or(false)
                {
                    ords.push(code[j + 3].text.clone());
                }
                j += 1;
            }
            if !ords.is_empty() {
                uses.push(UseSite {
                    file: rel.to_string(),
                    line,
                    recv,
                    field,
                    method: text.to_string(),
                    ords,
                    protocol,
                });
            }
        }
    }

    // Orphan contracts: every contract must sit with a declaration of
    // that name in the same file (annotations live at the decl site).
    let mut names: Vec<&Contract> = file_contracts.values().collect();
    names.sort_by_key(|c| c.line);
    for c in names {
        if !decls.iter().any(|d| d.name == c.name) {
            out.push(Violation::new(
                "contract-syntax",
                rel,
                c.line,
                format!("contract for atomic({}) matches no atomic declaration in this file", c.name),
            ));
        }
    }

    FileAnalysis { contracts: file_contracts, uses, decls }
}

/// Merge a file's contracts into the global registry, reporting
/// resolved-set conflicts (contracts are keyed tree-wide by field name,
/// so two modules naming a field `epoch` must mean the same protocol).
pub fn merge_contracts(
    global: &mut HashMap<String, Contract>,
    file: HashMap<String, Contract>,
    rel: &str,
    out: &mut Vec<Violation>,
) {
    let mut entries: Vec<(String, Contract)> = file.into_iter().collect();
    entries.sort_by_key(|(_, c)| c.line);
    for (name, c) in entries {
        match global.get(&name) {
            Some(prev) if !prev.same_resolved(&c) => {
                out.push(Violation::new(
                    "atomic-conflict",
                    rel,
                    c.line,
                    format!(
                        "contract for atomic({}) conflicts with {}:{} (`{}` vs `{}`)",
                        name, prev.file, prev.line, c.spec, prev.spec
                    ),
                ));
            }
            Some(_) => {}
            None => {
                global.insert(name, c);
            }
        }
    }
}
