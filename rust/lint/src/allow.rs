//! The allowlist: `rust/lint/allow.toml`, parsed by hand (a TOML crate
//! would violate the no-new-dependencies policy, and the format is a
//! flat array-of-tables with string values only).
//!
//! ```toml
//! [[allow]]
//! check = "no-panic"
//! file = "gpu/scheduler.rs"          # path suffix match
//! line_contains = "decode grid"      # substring of the flagged line
//! reason = "why this is sound"       # mandatory
//! ```
//!
//! Entries that match nothing are themselves reported (`allow-unused`)
//! so the list cannot rot as the code moves.

use crate::diag::Violation;
use std::collections::HashMap;
use std::path::Path;

#[derive(Debug, Default)]
pub struct AllowEntry {
    pub line: usize,
    pub check: Option<String>,
    pub file: Option<String>,
    pub line_contains: Option<String>,
    pub reason: Option<String>,
    pub used: bool,
}

impl AllowEntry {
    fn complete(&self) -> bool {
        self.check.is_some()
            && self.file.is_some()
            && self.line_contains.is_some()
            && self.reason.is_some()
    }
}

pub fn parse_allowlist(path: &Path, out: &mut Vec<Violation>) -> Vec<AllowEntry> {
    let src = match std::fs::read_to_string(path) {
        Ok(s) => s,
        Err(_) => return Vec::new(),
    };
    let path_str = path.display().to_string();
    let mut entries: Vec<AllowEntry> = Vec::new();
    for (i, raw) in src.lines().enumerate() {
        let lineno = i + 1;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line == "[[allow]]" {
            entries.push(AllowEntry { line: lineno, ..AllowEntry::default() });
            continue;
        }
        let parsed = parse_kv(line);
        match (parsed, entries.last_mut()) {
            (Some((k, v)), Some(cur)) => {
                let slot = match k {
                    "check" => &mut cur.check,
                    "file" => &mut cur.file,
                    "line_contains" => &mut cur.line_contains,
                    "reason" => &mut cur.reason,
                    _ => continue, // unknown keys tolerated, like the mirror
                };
                *slot = Some(v.to_string());
            }
            _ => {
                out.push(Violation::new(
                    "contract-syntax",
                    &path_str,
                    lineno,
                    format!("unparseable allowlist line: {line:?}"),
                ));
            }
        }
    }
    for e in &entries {
        for (req, val) in [
            ("check", &e.check),
            ("file", &e.file),
            ("line_contains", &e.line_contains),
            ("reason", &e.reason),
        ] {
            if val.is_none() {
                out.push(Violation::new(
                    "contract-syntax",
                    &path_str,
                    e.line,
                    format!("allowlist entry missing `{req}`"),
                ));
            }
        }
    }
    entries
}

/// `key = "value"` — value is everything between the first and last
/// quote; inner quotes pass through verbatim (reasons are prose).
fn parse_kv(line: &str) -> Option<(&str, &str)> {
    let (k, v) = line.split_once('=')?;
    let k = k.trim();
    if k.is_empty() || !k.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
        return None;
    }
    let v = v.trim();
    let v = v.strip_prefix('"')?.strip_suffix('"')?;
    Some((k, v))
}

/// Filter `out` through the entries: a violation is suppressed by the
/// first entry whose `check` matches exactly, whose `file` is a path
/// suffix of the violation's file, and whose `line_contains` is a
/// substring of the raw source line the violation points at. Unused
/// complete entries become `allow-unused` violations.
pub fn apply_allowlist(
    entries: &mut [AllowEntry],
    violations: Vec<Violation>,
    root: &Path,
) -> Vec<Violation> {
    let mut kept = Vec::new();
    let mut raw_cache: HashMap<String, Vec<String>> = HashMap::new();
    for v in violations {
        let mut suppressed = false;
        for e in entries.iter_mut() {
            if e.check.as_deref() != Some(v.check) {
                continue;
            }
            let suffix = match e.file.as_deref() {
                Some(f) => f,
                None => continue,
            };
            if !v.file.ends_with(suffix) {
                continue;
            }
            let needle = match e.line_contains.as_deref() {
                Some(n) => n,
                None => continue,
            };
            let lines = raw_cache.entry(v.file.clone()).or_insert_with(|| {
                let cand = root.join(&v.file);
                let p = if cand.exists() { cand } else { Path::new(&v.file).to_path_buf() };
                std::fs::read_to_string(p)
                    .map(|s| s.lines().map(String::from).collect())
                    .unwrap_or_default()
            });
            let src_line = if v.line >= 1 { lines.get(v.line - 1) } else { None };
            if src_line.map(|l| l.contains(needle)).unwrap_or(false) {
                e.used = true;
                suppressed = true;
                break;
            }
        }
        if !suppressed {
            kept.push(v);
        }
    }
    for e in entries.iter() {
        if !e.used && e.complete() {
            kept.push(Violation::new(
                "allow-unused",
                "allow.toml",
                e.line,
                format!(
                    "allowlist entry for `{}` at {} matched nothing",
                    e.check.as_deref().unwrap_or("?"),
                    e.file.as_deref().unwrap_or("?")
                ),
            ));
        }
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_parsing() {
        assert_eq!(parse_kv(r#"check = "no-panic""#), Some(("check", "no-panic")));
        assert_eq!(parse_kv(r#"reason = "a \"quoted\" word""#), Some(("reason", r#"a \"quoted\" word"#)));
        assert_eq!(parse_kv("check = no-panic"), None);
        assert_eq!(parse_kv("[[allow]]"), None);
    }
}
