//! Every check is demonstrated by a fixture pair under
//! `rust/lint/fixtures/<check>/{bad,good}`: the bad tree fires the
//! check (and nothing else), the good tree is the minimal fix and
//! lints clean. The `json_golden` tree pins the machine-report format
//! byte-for-byte.

use std::path::PathBuf;

fn fixture_root(name: &str, variant: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name).join(variant)
}

fn run(name: &str, variant: &str) -> blink_lint::Report {
    blink_lint::run(&fixture_root(name, variant))
        .unwrap_or_else(|e| panic!("fixture {name}/{variant}: {e}"))
}

/// bad/ must produce at least one violation, all of the named check;
/// good/ must be clean.
fn assert_pair(name: &str, check: &str) {
    let bad = run(name, "bad");
    assert!(!bad.violations.is_empty(), "{name}/bad fired nothing");
    for v in &bad.violations {
        assert_eq!(v.check, check, "{name}/bad fired an unexpected check: {v:?}");
    }
    let good = run(name, "good");
    assert!(
        good.clean(),
        "{name}/good must lint clean:\n{}",
        blink_lint::render_human(&good)
    );
}

#[test]
fn safety_comment_pair() {
    assert_pair("safety_comment", "safety-comment");
}

#[test]
fn no_alloc_pair() {
    assert_pair("no_alloc", "no-alloc");
}

#[test]
fn no_panic_pair() {
    assert_pair("no_panic", "no-panic");
}

#[test]
fn atomic_undeclared_pair() {
    // Fires twice in bad/: once at the declaration, once at the use.
    let bad = run("atomic_undeclared", "bad");
    assert_eq!(bad.violations.len(), 2, "{:?}", bad.violations);
    assert_pair("atomic_undeclared", "atomic-undeclared");
}

#[test]
fn atomic_ordering_pair() {
    let bad = run("atomic_ordering", "bad");
    assert_eq!(bad.violations.len(), 1, "{:?}", bad.violations);
    let v = &bad.violations[0];
    assert!(v.message.contains("`seq.store` uses Ordering::Relaxed"), "{v:?}");
    assert_eq!(v.contract.as_deref(), Some("atomic(seq) publish=Release observe=Acquire rmw=AcqRel"));
    assert_pair("atomic_ordering", "atomic-ordering");
}

#[test]
fn atomic_unpaired_pair() {
    let bad = run("atomic_unpaired", "bad");
    assert_eq!(bad.violations.len(), 1, "{:?}", bad.violations);
    assert!(bad.violations[0].message.contains("no acquire-side observer"));
    assert_pair("atomic_unpaired", "atomic-unpaired");
}

#[test]
fn atomic_conflict_pair() {
    let bad = run("atomic_conflict", "bad");
    assert_eq!(bad.violations.len(), 1, "{:?}", bad.violations);
    assert!(
        bad.violations[0].message.contains("conflicts with src/a.rs"),
        "{:?}",
        bad.violations[0]
    );
    assert_pair("atomic_conflict", "atomic-conflict");
}

#[test]
fn contract_syntax_pair() {
    assert_pair("contract_syntax", "contract-syntax");
}

#[test]
fn allow_unused_pair() {
    // bad/: clean source + a stale allow entry → the entry itself is
    // the violation. good/: a real violation suppressed by a scoped,
    // reasoned entry → fully clean.
    assert_pair("allow_unused", "allow-unused");
}

#[test]
fn json_report_matches_golden() {
    let root = fixture_root("json_golden", "");
    let report = blink_lint::run(&root).expect("json_golden run");
    let got = blink_lint::render_json(&report);
    let expected = std::fs::read_to_string(root.join("expected.json")).expect("expected.json");
    assert_eq!(got, expected.trim_end(), "JSON report drifted from the golden file");
}

#[test]
fn violations_are_sorted() {
    let report = run("json_golden", "");
    let mut keys: Vec<_> = report.violations.iter().map(|v| v.key()).collect();
    let sorted = {
        let mut s = keys.clone();
        s.sort();
        s
    };
    assert_eq!(keys, sorted);
    keys.dedup();
    assert_eq!(keys.len(), report.violations.len(), "duplicate diagnostics");
}
