//! Fixture: a well-formed contract at its declaration.

use std::sync::atomic::AtomicU64;

pub struct C {
    // lint: atomic(seq) counter
    pub seq: AtomicU64,
}
