//! Fixture: a typo'd ordering name in the contract spec.

use std::sync::atomic::AtomicU64;

pub struct C {
    // lint: atomic(seq) publish=Released
    pub seq: AtomicU64,
}
