use std::sync::atomic::{AtomicU64, Ordering};

pub struct S {
    // lint: atomic(epoch) publish=Release observe=Acquire rmw=AcqRel
    pub epoch: AtomicU64,
}

impl S {
    pub fn bump(&self) {
        self.epoch.store(1, Ordering::SeqCst);
    }
    pub fn read(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }
}
