// lint: no_panic
pub fn f(x: Option<u32>) -> u32 {
    x.unwrap()
}

pub fn g(p: *const u8) -> u8 {
    unsafe { *p }
}
