//! Fixture: `unsafe` with no SAFETY comment anywhere near it.

pub fn peek(p: *const u8) -> u8 {
    unsafe { *p }
}
