//! Fixture: every `unsafe` carries a SAFETY comment directly above.

pub fn peek(p: *const u8) -> u8 {
    // SAFETY: callers pass pointers derived from live slices (fixture).
    unsafe { *p }
}
