//! Fixture: an atomic field in a protocol module (`ringbuf/`) with no
//! contract annotation, plus a use of it.

use std::sync::atomic::{AtomicU32, Ordering};

pub struct Slot {
    pub state: AtomicU32,
}

impl Slot {
    pub fn tick(&self) -> u32 {
        self.state.fetch_add(1, Ordering::Relaxed)
    }
}
