//! Fixture: the same protocol-module atomic, now under contract.

use std::sync::atomic::{AtomicU32, Ordering};

pub struct Slot {
    // lint: atomic(state) counter
    pub state: AtomicU32,
}

impl Slot {
    pub fn tick(&self) -> u32 {
        self.state.fetch_add(1, Ordering::Relaxed)
    }
}
