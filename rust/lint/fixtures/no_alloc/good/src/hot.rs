//! Fixture: the hot path reuses caller-provided capacity instead.

// lint: no_alloc
pub fn bump_all_into(xs: &[u32], out: &mut Vec<u32>) {
    out.clear();
    out.extend(xs.iter().map(|x| x + 1));
}

/// Untagged helpers may allocate freely.
pub fn bump_all(xs: &[u32]) -> Vec<u32> {
    let mut out = Vec::new();
    bump_all_into(xs, &mut out);
    out
}
