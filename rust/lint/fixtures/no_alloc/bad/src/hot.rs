//! Fixture: allocation in a tagged hot-path region.

// lint: no_alloc
pub fn bump_all(xs: &[u32]) -> Vec<u32> {
    let mut out = Vec::new();
    out.extend(xs.iter().map(|x| x + 1));
    out
}
