//! Fixture: the contract mandates release publishes, but nothing in
//! the tree ever observes with acquire — the Release is decoration.

use std::sync::atomic::{AtomicU32, Ordering};

pub struct Gate {
    // lint: atomic(ready) publish=Release observe=Acquire|Relaxed
    pub ready: AtomicU32,
}

impl Gate {
    pub fn open(&self) {
        self.ready.store(1, Ordering::Release);
    }
    pub fn peek(&self) -> u32 {
        self.ready.load(Ordering::Relaxed)
    }
}
