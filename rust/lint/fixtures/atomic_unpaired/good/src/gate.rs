//! Fixture: the release publish has an acquire-side counterpart.

use std::sync::atomic::{AtomicU32, Ordering};

pub struct Gate {
    // lint: atomic(ready) publish=Release observe=Acquire|Relaxed
    pub ready: AtomicU32,
}

impl Gate {
    pub fn open(&self) {
        self.ready.store(1, Ordering::Release);
    }
    pub fn wait_open(&self) -> u32 {
        self.ready.load(Ordering::Acquire)
    }
    pub fn peek(&self) -> u32 {
        self.ready.load(Ordering::Relaxed)
    }
}
