//! Fixture: `.unwrap()` in a tagged no_panic region.

// lint: no_panic
pub fn first(xs: &[u32]) -> u32 {
    *xs.first().unwrap()
}
