//! Fixture: the hot path degrades instead of panicking; asserts stay
//! legal (invariant checks are allowed under no_panic).

// lint: no_panic
pub fn first(xs: &[u32]) -> u32 {
    debug_assert!(xs.len() < usize::MAX);
    match xs.first() {
        Some(x) => *x,
        None => 0,
    }
}
