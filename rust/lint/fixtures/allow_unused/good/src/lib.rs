//! Fixture: one real no_panic violation, suppressed by a scoped,
//! reasoned allowlist entry.

// lint: no_panic
pub fn first(xs: &[u32]) -> u32 {
    *xs.first().expect("fixture: callers pass non-empty slices")
}
