//! Fixture: clean source — so the stale allowlist entry below matches
//! nothing and must be reported.

pub fn nothing_to_see() -> u32 {
    7
}
