//! Fixture (1/2): both files agree on what `epoch` means.

use std::sync::atomic::AtomicU64;

pub struct A {
    // lint: atomic(epoch) counter
    pub epoch: AtomicU64,
}
