//! Fixture (2/2): identical resolved contract — a differently spelled
//! but equivalent spec would also merge cleanly.

use std::sync::atomic::AtomicU64;

pub struct B {
    // lint: atomic(epoch) counter
    pub epoch: AtomicU64,
}
