//! Fixture (1/2): `epoch` declared as a plain counter here...

use std::sync::atomic::AtomicU64;

pub struct A {
    // lint: atomic(epoch) counter
    pub epoch: AtomicU64,
}
