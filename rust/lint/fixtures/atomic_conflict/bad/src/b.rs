//! Fixture (2/2): ...and as a release/acquire edge here. Contracts are
//! keyed tree-wide by field name, so this is a conflict.

use std::sync::atomic::AtomicU64;

pub struct B {
    // lint: atomic(epoch) publish=Release observe=Acquire
    pub epoch: AtomicU64,
}
