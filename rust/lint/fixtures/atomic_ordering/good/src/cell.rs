//! Fixture: every ordering sits inside the contract's lists.

use std::sync::atomic::{AtomicU64, Ordering};

pub struct Cell {
    // lint: atomic(seq) publish=Release observe=Acquire rmw=AcqRel
    pub seq: AtomicU64,
}

impl Cell {
    pub fn publish(&self) {
        self.seq.store(1, Ordering::Release);
    }
    pub fn bump(&self) -> u64 {
        self.seq.fetch_add(1, Ordering::AcqRel)
    }
    pub fn read(&self) -> u64 {
        self.seq.load(Ordering::Acquire)
    }
}
