//! Fixture: a store whose ordering is weaker than the contract's
//! publish list.

use std::sync::atomic::{AtomicU64, Ordering};

pub struct Cell {
    // lint: atomic(seq) publish=Release observe=Acquire rmw=AcqRel
    pub seq: AtomicU64,
}

impl Cell {
    pub fn publish(&self) {
        self.seq.store(1, Ordering::Relaxed);
    }
    pub fn bump(&self) -> u64 {
        self.seq.fetch_add(1, Ordering::AcqRel)
    }
    pub fn read(&self) -> u64 {
        self.seq.load(Ordering::Acquire)
    }
}
