//! Stub of the `xla` (PJRT) bindings, vendored so the workspace builds
//! without the native XLA/PJRT toolchain. The API surface matches what
//! `blink::runtime` uses; every entry point reports a clear
//! "PJRT unavailable" error at *runtime*, and `PjRtClient::cpu()` fails
//! first, so `Engine::load` returns an error before any other stub method
//! can be reached. Integration tests check for AOT artifacts before
//! loading an engine and skip when absent, which keeps `cargo test` green
//! on machines without the real bindings; swapping this path dependency
//! for the real `xla` crate re-enables live execution with no source
//! changes in `blink`.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime not linked (stub `xla` crate; vendor the real bindings to run live)"
    ))
}

/// Marker trait mirrored from the real crate (used for npz loading).
pub trait FromRawBytes: Sized {}

impl FromRawBytes for f32 {}
impl FromRawBytes for i32 {}
impl FromRawBytes for u32 {}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn read_npz_by_name<P: AsRef<Path>>(
        _path: P,
        _client: &PjRtClient,
        _names: &[&str],
    ) -> Result<Vec<PjRtBuffer>> {
        Err(unavailable("PjRtBuffer::read_npz_by_name"))
    }

    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b_untupled(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b_untupled"))
    }
}

pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("PJRT"), "{err}");
    }
}
