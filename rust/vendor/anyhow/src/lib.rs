//! Minimal, API-compatible subset of the `anyhow` crate, vendored so the
//! workspace builds in offline environments (crates.io is unreachable in
//! the reproduction container). Covers exactly what the blink crate uses:
//! `Error`, `Result`, `anyhow!`, `bail!`, and the `Context` extension
//! trait for `Result` and `Option`, including `{e}` / `{e:#}` formatting
//! of context chains.

use std::fmt;

/// Opaque error: a message plus an optional chain of wrapped causes.
/// Like the real `anyhow::Error`, this intentionally does NOT implement
/// `std::error::Error`, which is what allows the blanket `From` below.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string(), source: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: ctx.to_string(), source: Some(Box::new(self)) }
    }

    /// Outermost message (no chain).
    pub fn root_message(&self) -> &str {
        &self.msg
    }

    fn write_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        while let Some(e) = cur {
            write!(f, ": {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full context chain, outermost first.
            self.write_chain(f)
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.write_chain(f)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msgs: Vec<String> = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(Error { msg: m, source: err.map(Box::new) });
        }
        err.expect("at least one message")
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(|| ..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string, or from any `Display`
/// value (`anyhow!(err)`), mirroring the real macro's arms.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return an `Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::{Context, Error, Result};

    #[test]
    fn chain_formatting() {
        let e = Error::msg("inner").context("mid").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: mid: inner");
    }

    #[test]
    fn from_std_error_and_question_mark() {
        fn parse() -> Result<i32> {
            let n: i32 = "12x".parse()?;
            Ok(n)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn option_context() {
        let r: Result<i32> = None.context("missing value");
        assert_eq!(format!("{}", r.unwrap_err()), "missing value");
    }

    #[test]
    fn macros() {
        fn f(flag: bool) -> Result<i32> {
            if flag {
                bail!("flag was {flag}");
            }
            Err(anyhow!("fell through {}", 7))
        }
        assert_eq!(format!("{}", f(true).unwrap_err()), "flag was true");
        assert_eq!(format!("{}", f(false).unwrap_err()), "fell through 7");
        // Expression arm: any Display value.
        let owned = String::from("owned message");
        assert_eq!(format!("{}", anyhow!(owned)), "owned message");
    }
}
