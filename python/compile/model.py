"""L2: the JAX model — llama-style decoder (dense + MoE) with paged KV.

This is the compute graph Blink's GPU-resident scheduler launches: three
entry points, ``prefill``, ``prefill_offset`` (suffix prefill at a runtime
offset, behind live prefix-cache hits) and ``decode_step``, all *pure
functions* of (params, kv_pool, control tensors, seed). They call the L1
Pallas kernels
(``use_pallas=True``, the AOT default) or the jnp oracles (``False``) —
the A/B used by python/tests to validate kernels inside the full graph.

Conventions shared with the rust coordinator (rust/src/runtime,
rust/src/gpu) — change them in lockstep with artifacts/manifest:

* KV pool: [L, N, 2, Hkv, Bs, Dh] float32, device-resident across steps.
* block_tables: [B, M] int32. Every entry that any *padded* position of a
  sequence can map to must be a block owned by that sequence (the rust
  allocator allocates ceil(padded_len / Bs) blocks up front), because
  prefill writes K/V for padded positions too (masked out of attention,
  overwritten by later decode writes).
* seq_lens (decode): number of tokens whose K/V is already cached; the
  incoming token is written at position seq_lens and attention spans
  seq_lens + 1 tokens.
* seed: uint32 scalar; all sampling randomness derives from it, so the
  rust side replays generations deterministically.
"""

import dataclasses
import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "blink-tiny"
    vocab_size: int = 2048
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    n_kv_heads: int = 4
    d_head: int = 32
    d_ff: int = 704
    rope_theta: float = 10000.0
    # Paged KV cache geometry.
    block_size: int = 16
    num_blocks: int = 512
    max_blocks_per_seq: int = 32  # max context = 512 tokens
    # MoE.
    moe: bool = False
    n_experts: int = 4
    top_k: int = 2
    # Sampling (captured inside the graph, like the paper's CUDA graphs).
    temperature: float = 0.8
    top_p: float = 0.95
    eos_token: int = 0

    @property
    def max_context(self) -> int:
        return self.block_size * self.max_blocks_per_seq

    def param_specs(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Ordered (name, shape) list — the manifest/npz/rust arg order."""
        l, d = self.n_layers, self.d_model
        hq, hkv, dh, f = self.n_heads, self.n_kv_heads, self.d_head, self.d_ff
        specs = [
            ("tok_embed", (self.vocab_size, d)),
            ("attn_norm", (l, d)),
            ("wq", (l, d, hq * dh)),
            ("wk", (l, d, hkv * dh)),
            ("wv", (l, d, hkv * dh)),
            ("wo", (l, hq * dh, d)),
            ("mlp_norm", (l, d)),
        ]
        if self.moe:
            e = self.n_experts
            specs += [
                ("router", (l, d, e)),
                ("w_gate", (l, e, d, f)),
                ("w_up", (l, e, d, f)),
                ("w_down", (l, e, f, d)),
            ]
        else:
            specs += [
                ("w_gate", (l, d, f)),
                ("w_up", (l, d, f)),
                ("w_down", (l, f, d)),
            ]
        specs.append(("final_norm", (d,)))
        return specs

    def param_count(self) -> int:
        return sum(int(jnp.prod(jnp.array(s))) for _, s in self.param_specs())


TINY = ModelConfig()
TINY_MOE = ModelConfig(name="blink-tiny-moe", moe=True, d_ff=512)

# The four paper models, used by the simulator cost model (sim::costmodel
# in rust mirrors these numbers; they are not instantiated as real weights).
PAPER_MODELS = {
    "llama3-8b": dict(params=8.0e9, active=8.0e9, layers=32, moe=False),
    "phi4-15b": dict(params=14.7e9, active=14.7e9, layers=40, moe=False),
    "qwen3-32b": dict(params=32.0e9, active=32.0e9, layers=64, moe=False),
    "qwen3-30b-a3b": dict(params=30.0e9, active=3.0e9, layers=48, moe=True),
}


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jax.Array]:
    """Deterministic random init, scaled for stable logits at depth."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in cfg.param_specs():
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            params[name] = (
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(float(fan_in))
            )
    return params


# ---------------------------------------------------------------------------
# Building blocks (kernel / oracle switched by use_pallas)
# ---------------------------------------------------------------------------


def _rmsnorm(x2d, w, use_pallas):
    return kernels.rmsnorm(x2d, w) if use_pallas else ref.rmsnorm_ref(x2d, w)


def _rope(x, pos, theta, use_pallas):
    # x: [T, H, Dh], pos: [T]
    return kernels.rope(x, pos, theta=theta) if use_pallas else ref.rope_ref(x, pos, theta)


def _sample(logits, uniform, cfg, use_pallas):
    fn = kernels.topp_sample if use_pallas else ref.topp_sample_ref
    return fn(logits, uniform, temperature=cfg.temperature, top_p=cfg.top_p)


def _mlp_dense(h2d, wg, wu, wd):
    g = jax.nn.silu(h2d @ wg)
    return (g * (h2d @ wu)) @ wd


def _mlp_moe(h2d, router, wg, wu, wd, cfg, use_pallas):
    # h2d: [T, D]; router: [D, E]; wg/wu: [E, D, F]; wd: [E, F, D].
    gate_logits = h2d @ router  # [T, E]
    if use_pallas:
        weights = kernels.moe_gating(gate_logits, top_k=cfg.top_k)
    else:
        weights, _ = ref.moe_gating_ref(gate_logits, top_k=cfg.top_k)
    # Fixed-shape dispatch (paper §6.2): every expert runs on every token,
    # outputs combined by the (mostly-zero) dense routing weights. This is
    # the shape-static capture TensorRT's MoE plugin performs with fixed
    # buffers; compute waste is irrelevant at tiny scale and the HLO stays
    # branch-free.
    g = jax.nn.silu(jnp.einsum("td,edf->tef", h2d, wg))
    u = jnp.einsum("td,edf->tef", h2d, wu)
    eo = jnp.einsum("tef,efd->ted", g * u, wd)  # [T, E, D]
    return jnp.einsum("te,ted->td", weights, eo)


def _mlp(h2d, p, li, cfg, use_pallas):
    if cfg.moe:
        return _mlp_moe(
            h2d,
            p["router"][li],
            p["w_gate"][li],
            p["w_up"][li],
            p["w_down"][li],
            cfg,
            use_pallas,
        )
    return _mlp_dense(h2d, p["w_gate"][li], p["w_up"][li], p["w_down"][li])


def _write_kv_decode(pool_layer, k, v, block_tables, positions, cfg):
    """Write one token's K/V per sequence into the pool.

    pool_layer: [N, 2, Hkv, Bs, Dh]; k/v: [B, Hkv, Dh]; positions: [B]."""
    bs = cfg.block_size
    blk = jnp.take_along_axis(block_tables, (positions // bs)[:, None], axis=1)[:, 0]
    slot = positions % bs
    pool_layer = pool_layer.at[blk, 0, :, slot, :].set(k)
    pool_layer = pool_layer.at[blk, 1, :, slot, :].set(v)
    return pool_layer


def _write_kv_prefill(pool_layer, k, v, block_tables, cfg):
    """Write a whole padded prompt's K/V. k/v: [B, S, Hkv, Dh]."""
    b, s = k.shape[0], k.shape[1]
    bs = cfg.block_size
    pos = jnp.arange(s, dtype=jnp.int32)
    blk = block_tables[:, :][jnp.arange(b)[:, None], pos[None, :] // bs]  # [B, S]
    slot = pos[None, :] % bs  # [1, S] -> broadcast
    slot = jnp.broadcast_to(slot, (b, s))
    # k is [B, S, Hkv, Dh]; advanced indices (blk, slot) pick [B, S] slots.
    pool_layer = pool_layer.at[blk, 0, :, slot, :].set(jnp.moveaxis(k, 2, 2))
    pool_layer = pool_layer.at[blk, 1, :, slot, :].set(jnp.moveaxis(v, 2, 2))
    return pool_layer


def _write_kv_prefill_offset(pool_layer, k, v, block_tables, offsets, cfg):
    """Write a padded *suffix*'s K/V at positions offsets..offsets+S.

    k/v: [B, S, Hkv, Dh]; offsets: [B] int32 (runtime, block-aligned
    cached-prefix lengths). The block-table entries these positions map to
    are owned by the sequence (the rust allocator reserves the full
    cached+padded span), so padded writes are benign exactly as in
    `_write_kv_prefill`."""
    b, s = k.shape[0], k.shape[1]
    bs = cfg.block_size
    pos = offsets[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]  # [B, S]
    blk = block_tables[jnp.arange(b)[:, None], pos // bs]
    slot = pos % bs
    pool_layer = pool_layer.at[blk, 0, :, slot, :].set(k)
    pool_layer = pool_layer.at[blk, 1, :, slot, :].set(v)
    return pool_layer


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def decode_step(
    params: Dict[str, jax.Array],
    kv_pool: jax.Array,
    block_tables: jax.Array,
    seq_lens: jax.Array,
    tokens: jax.Array,
    seed: jax.Array,
    cfg: ModelConfig,
    use_pallas: bool = True,
):
    """One decode iteration for a batch.

    kv_pool: [L, N, 2, Hkv, Bs, Dh]; tokens: [B] int32 (current inputs);
    seq_lens: [B] cached-token counts. Returns (next_tokens [B], kv_pool').
    Inactive batch lanes (seq_lens == 0 convention is NOT used — the rust
    side packs active lanes densely and pads with lane 0 duplicates).
    """
    b = tokens.shape[0]
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    positions = seq_lens  # write position of the incoming token

    x = params["tok_embed"][tokens]  # [B, D]

    def layer(carry, inputs):
        x, kv_pool = carry
        li = inputs
        h = _rmsnorm(x, params["attn_norm"][li], use_pallas)
        q = (h @ params["wq"][li]).reshape(b, hq, dh)
        k = (h @ params["wk"][li]).reshape(b, hkv, dh)
        v = (h @ params["wv"][li]).reshape(b, hkv, dh)
        # rope over the "token" axis: decode has T == B independent tokens.
        q = _rope(q, positions, cfg.rope_theta, use_pallas)
        k = _rope(k, positions, cfg.rope_theta, use_pallas)
        pool_layer = kv_pool[li]
        pool_layer = _write_kv_decode(pool_layer, k, v, block_tables, positions, cfg)
        kv_pool = jax.lax.dynamic_update_index_in_dim(kv_pool, pool_layer, li, 0)
        attn_fn = kernels.paged_attention if use_pallas else ref.paged_attention_ref
        o = attn_fn(q, pool_layer, block_tables, seq_lens + 1)  # [B, Hq, Dh]
        x = x + o.reshape(b, hq * dh) @ params["wo"][li]
        h2 = _rmsnorm(x, params["mlp_norm"][li], use_pallas)
        x = x + _mlp(h2, params, li, cfg, use_pallas)
        return (x, kv_pool), None

    (x, kv_pool), _ = jax.lax.scan(
        layer, (x, kv_pool), jnp.arange(cfg.n_layers), length=cfg.n_layers
    )

    x = _rmsnorm(x, params["final_norm"], use_pallas)
    logits = x @ params["tok_embed"].T  # tied LM head, [B, V]
    uniform = jax.random.uniform(jax.random.PRNGKey(seed), (b,), jnp.float32)
    next_tokens = _sample(logits, uniform, cfg, use_pallas)
    return next_tokens.astype(jnp.int32), kv_pool


def prefill(
    params: Dict[str, jax.Array],
    kv_pool: jax.Array,
    block_tables: jax.Array,
    seq_lens: jax.Array,
    tokens: jax.Array,
    seed: jax.Array,
    cfg: ModelConfig,
    use_pallas: bool = True,
    return_logits: bool = False,
):
    """Prefill a padded batch of prompts and sample each first output token.

    tokens: [B, S] int32 (padded with any id); seq_lens: [B] true lengths.
    Writes K/V for all S positions (padded ones are masked in attention and
    later overwritten by decode). Returns (first_tokens [B], kv_pool');
    with `return_logits` (tests only, not exported) the last-position
    logits [B, V] replace the sampled tokens.
    """
    b, s = tokens.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    positions = jnp.arange(s, dtype=jnp.int32)

    x = params["tok_embed"][tokens]  # [B, S, D]

    def layer(carry, li):
        x, kv_pool = carry
        h2d = _rmsnorm(x.reshape(b * s, -1), params["attn_norm"][li], use_pallas)
        h = h2d.reshape(b, s, -1)
        q = (h @ params["wq"][li]).reshape(b, s, hq, dh)
        k = (h @ params["wk"][li]).reshape(b, s, hkv, dh)
        v = (h @ params["wv"][li]).reshape(b, s, hkv, dh)
        # rope rows share positions across the batch: flatten to [B*S].
        posf = jnp.broadcast_to(positions[None, :], (b, s)).reshape(b * s)
        q = _rope(q.reshape(b * s, hq, dh), posf, cfg.rope_theta, use_pallas).reshape(
            b, s, hq, dh
        )
        k = _rope(k.reshape(b * s, hkv, dh), posf, cfg.rope_theta, use_pallas).reshape(
            b, s, hkv, dh
        )
        pool_layer = kv_pool[li]
        pool_layer = _write_kv_prefill(pool_layer, k, v, block_tables, cfg)
        kv_pool = jax.lax.dynamic_update_index_in_dim(kv_pool, pool_layer, li, 0)
        attn_fn = kernels.flash_attention if use_pallas else ref.flash_attention_ref
        o = attn_fn(q, k, v, seq_lens)  # [B, S, Hq, Dh]
        x = x + o.reshape(b, s, hq * dh) @ params["wo"][li]
        h2 = _rmsnorm(x.reshape(b * s, -1), params["mlp_norm"][li], use_pallas)
        x = x + _mlp(h2, params, li, cfg, use_pallas).reshape(b, s, -1)
        return (x, kv_pool), None

    (x, kv_pool), _ = jax.lax.scan(
        layer, (x, kv_pool), jnp.arange(cfg.n_layers), length=cfg.n_layers
    )

    # Last valid hidden state per row -> first sampled token.
    last_idx = jnp.clip(seq_lens - 1, 0, s - 1)
    xl = jnp.take_along_axis(x, last_idx[:, None, None], axis=1)[:, 0]  # [B, D]
    xl = _rmsnorm(xl, params["final_norm"], use_pallas)
    logits = xl @ params["tok_embed"].T
    if return_logits:
        return logits, kv_pool
    uniform = jax.random.uniform(jax.random.PRNGKey(seed), (b,), jnp.float32)
    first = _sample(logits, uniform, cfg, use_pallas)
    return first.astype(jnp.int32), kv_pool


def prefill_offset(
    params: Dict[str, jax.Array],
    kv_pool: jax.Array,
    block_tables: jax.Array,
    seq_lens: jax.Array,
    tokens: jax.Array,
    offsets: jax.Array,
    seed: jax.Array,
    cfg: ModelConfig,
    use_pallas: bool = True,
    return_logits: bool = False,
):
    """Prefill a padded batch of prompt *suffixes* at runtime offsets.

    The offset-graph variant behind live prefix-cache hits (DESIGN.md §7):
    the leading `offsets[b]` tokens of each prompt are already cached in
    the paged pool (their K/V written by an earlier prefill of the shared
    prefix), so this graph only processes the uncached suffix — rotary
    embeddings and KV writes land at the true positions
    ``offsets[b] .. offsets[b] + S`` and attention spans the whole cached
    context via the pool (`kernels.paged_prefill_attention`, or the
    `paged_prefill_attention_ref` oracle). ``offsets`` is a
    runtime [B] int32 input, so one compiled (B, S) graph serves every
    block-aligned hit length; a row with offset 0 degenerates to an
    ordinary causal prefill over the pool.

    tokens: [B, S] int32 suffix tokens (padded with any id);
    offsets: [B] int32 block-aligned cached-prefix lengths;
    seq_lens: [B] FULL true lengths (offset + true suffix length).
    Returns (first_tokens [B], kv_pool'), or (logits [B, V], kv_pool')
    with `return_logits` (tests only, not exported).
    """
    b, s = tokens.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    pos = offsets[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]  # [B, S]

    x = params["tok_embed"][tokens]  # [B, S, D]

    def layer(carry, li):
        x, kv_pool = carry
        h2d = _rmsnorm(x.reshape(b * s, -1), params["attn_norm"][li], use_pallas)
        h = h2d.reshape(b, s, -1)
        q = (h @ params["wq"][li]).reshape(b, s, hq, dh)
        k = (h @ params["wk"][li]).reshape(b, s, hkv, dh)
        v = (h @ params["wv"][li]).reshape(b, s, hkv, dh)
        # rope at the *global* positions of the suffix rows.
        posf = pos.reshape(b * s)
        q = _rope(q.reshape(b * s, hq, dh), posf, cfg.rope_theta, use_pallas).reshape(
            b, s, hq, dh
        )
        k = _rope(k.reshape(b * s, hkv, dh), posf, cfg.rope_theta, use_pallas).reshape(
            b, s, hkv, dh
        )
        pool_layer = kv_pool[li]
        pool_layer = _write_kv_prefill_offset(pool_layer, k, v, block_tables, offsets, cfg)
        kv_pool = jax.lax.dynamic_update_index_in_dim(kv_pool, pool_layer, li, 0)
        # Attention spans cached prefix + fresh suffix K/V through the
        # pool: the fused Pallas kernel streams pages block-by-block
        # with causal masking at true global positions, the jnp
        # gather/einsum composition stays the oracle — dispatch is now
        # uniform with decode/prefill.
        attn_fn = (
            kernels.paged_prefill_attention
            if use_pallas
            else ref.paged_prefill_attention_ref
        )
        o = attn_fn(q, pool_layer, block_tables, offsets)
        x = x + o.reshape(b, s, hq * dh) @ params["wo"][li]
        h2 = _rmsnorm(x.reshape(b * s, -1), params["mlp_norm"][li], use_pallas)
        x = x + _mlp(h2, params, li, cfg, use_pallas).reshape(b, s, -1)
        return (x, kv_pool), None

    (x, kv_pool), _ = jax.lax.scan(
        layer, (x, kv_pool), jnp.arange(cfg.n_layers), length=cfg.n_layers
    )

    # Last valid *suffix* row per sequence -> first sampled token.
    last_idx = jnp.clip(seq_lens - 1 - offsets, 0, s - 1)
    xl = jnp.take_along_axis(x, last_idx[:, None, None], axis=1)[:, 0]  # [B, D]
    xl = _rmsnorm(xl, params["final_norm"], use_pallas)
    logits = xl @ params["tok_embed"].T
    if return_logits:
        return logits, kv_pool
    uniform = jax.random.uniform(jax.random.PRNGKey(seed), (b,), jnp.float32)
    first = _sample(logits, uniform, cfg, use_pallas)
    return first.astype(jnp.int32), kv_pool


def decode_verify(
    params: Dict[str, jax.Array],
    kv_pool: jax.Array,
    block_tables: jax.Array,
    seq_lens: jax.Array,
    tokens: jax.Array,
    seed: jax.Array,
    cfg: ModelConfig,
    use_pallas: bool = True,
    return_logits: bool = False,
):
    """Draft-verify decode: one k-wide launch scores k drafted tokens.

    tokens: [B, S] int32 with S = k+1 — column 0 is the lane's pending
    last token (exactly the input ``decode_step`` would take) and columns
    1..k are the self-drafted candidates. seq_lens: [B] cached-token
    counts, as in decode. K/V for all S input tokens is written at true
    positions ``seq_lens .. seq_lens + k`` (the same pool-write the k+1
    equivalent sequential decode steps would do), RoPE is applied at
    those positions, and attention spans the whole cached context through
    the paged pool — structurally this is ``prefill_offset`` with
    ``offsets = seq_lens``, except that *every* query position samples a
    next token rather than only the last row.

    Returns (out_tokens [B, S], kv_pool'): out_tokens[:, j] is the
    sampled successor of input position j — the verdict for draft j+1,
    and at the first rejected position, the bonus token. The rust
    scheduler accepts the longest prefix with drafts[j+1] == out[j] and
    rolls back the K/V of rejected positions. S = 1 (k = 0) degenerates
    to ``decode_step`` exactly: same flattened sampling stream, same
    pool write.
    """
    b, s = tokens.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    pos = seq_lens[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]  # [B, S]

    x = params["tok_embed"][tokens]  # [B, S, D]

    def layer(carry, li):
        x, kv_pool = carry
        h2d = _rmsnorm(x.reshape(b * s, -1), params["attn_norm"][li], use_pallas)
        h = h2d.reshape(b, s, -1)
        q = (h @ params["wq"][li]).reshape(b, s, hq, dh)
        k = (h @ params["wk"][li]).reshape(b, s, hkv, dh)
        v = (h @ params["wv"][li]).reshape(b, s, hkv, dh)
        posf = pos.reshape(b * s)
        q = _rope(q.reshape(b * s, hq, dh), posf, cfg.rope_theta, use_pallas).reshape(
            b, s, hq, dh
        )
        k = _rope(k.reshape(b * s, hkv, dh), posf, cfg.rope_theta, use_pallas).reshape(
            b, s, hkv, dh
        )
        pool_layer = kv_pool[li]
        pool_layer = _write_kv_prefill_offset(
            pool_layer, k, v, block_tables, seq_lens, cfg
        )
        kv_pool = jax.lax.dynamic_update_index_in_dim(kv_pool, pool_layer, li, 0)
        attn_fn = (
            kernels.paged_prefill_attention
            if use_pallas
            else ref.paged_prefill_attention_ref
        )
        o = attn_fn(q, pool_layer, block_tables, seq_lens)
        x = x + o.reshape(b, s, hq * dh) @ params["wo"][li]
        h2 = _rmsnorm(x.reshape(b * s, -1), params["mlp_norm"][li], use_pallas)
        x = x + _mlp(h2, params, li, cfg, use_pallas).reshape(b, s, -1)
        return (x, kv_pool), None

    (x, kv_pool), _ = jax.lax.scan(
        layer, (x, kv_pool), jnp.arange(cfg.n_layers), length=cfg.n_layers
    )

    # Every query position produces a next-token distribution.
    x2d = _rmsnorm(x.reshape(b * s, -1), params["final_norm"], use_pallas)
    logits = x2d @ params["tok_embed"].T  # [B*S, V]
    if return_logits:
        return logits.reshape(b, s, -1), kv_pool
    uniform = jax.random.uniform(jax.random.PRNGKey(seed), (b * s,), jnp.float32)
    out = _sample(logits, uniform, cfg, use_pallas)
    return out.astype(jnp.int32).reshape(b, s), kv_pool


# ---------------------------------------------------------------------------
# Flat-argument wrappers for AOT export (rust passes positional buffers)
# ---------------------------------------------------------------------------


def make_flat_fns(cfg: ModelConfig, use_pallas: bool = True):
    """Return (decode_fn, prefill_fn, prefill_offset_fn, decode_verify_fn)
    taking flat positional args in manifest order:
    [*params, kv_pool, block_tables, seq_lens, tokens, seed] — the offset
    variant takes an extra [B] int32 `offsets` between tokens and seed;
    the verify variant's tokens are [B, k+1] (last token + k drafts) and
    it needs no offsets input because seq_lens already carries the write
    positions. Outputs are (next_tokens, kv_pool) tuples."""
    names = [n for n, _ in cfg.param_specs()]

    def unflatten(args):
        params = dict(zip(names, args[: len(names)]))
        rest = args[len(names):]
        return params, rest

    def decode_fn(*args):
        params, (kv, bt, sl, tok, seed) = unflatten(args)
        return decode_step(params, kv, bt, sl, tok, seed, cfg, use_pallas)

    def prefill_fn(*args):
        params, (kv, bt, sl, tok, seed) = unflatten(args)
        return prefill(params, kv, bt, sl, tok, seed, cfg, use_pallas)

    def prefill_offset_fn(*args):
        params, (kv, bt, sl, tok, off, seed) = unflatten(args)
        return prefill_offset(params, kv, bt, sl, tok, off, seed, cfg, use_pallas)

    def decode_verify_fn(*args):
        params, (kv, bt, sl, tok, seed) = unflatten(args)
        return decode_verify(params, kv, bt, sl, tok, seed, cfg, use_pallas)

    return decode_fn, prefill_fn, prefill_offset_fn, decode_verify_fn


def empty_kv_pool(cfg: ModelConfig) -> jax.Array:
    return jnp.zeros(
        (
            cfg.n_layers,
            cfg.num_blocks,
            2,
            cfg.n_kv_heads,
            cfg.block_size,
            cfg.d_head,
        ),
        jnp.float32,
    )
