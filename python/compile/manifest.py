"""Manifest + graph-grid declarations for the AOT export — jax-free.

``aot.py`` lowers graphs with JAX and then writes the manifest the rust
runtime parses (rust/src/runtime/manifest.rs). The *content* of that
manifest — which fields, which graph grid, in what order — is pure data,
so it lives here where tests can exercise it without a JAX install: the
manifest is the contract between the python exporter and the rust
coordinator, and the contract should be checkable everywhere the tests
run.

``manifest_text`` duck-types its config (anything with the ModelConfig
field names and ``param_specs()``), which is what keeps this module
import-clean: ``model.ModelConfig`` itself lives behind a jax import.
"""

from typing import List, Tuple

# The (batch, seq) graph grids. Decode graphs are keyed by batch size;
# prefill graphs by (batch, padded seq len). The MoE grid is smaller —
# expert dispatch multiplies lowering time and the sparse model exists to
# prove the path, not to chase throughput.
DENSE_DECODE_BATCHES = [1, 2, 4, 8, 16]
DENSE_PREFILL_GRID = [
    (b, s) for b in (1, 2, 4) for s in (16, 32, 64, 128, 256)
]
MOE_DECODE_BATCHES = [1, 2, 4, 8]
MOE_PREFILL_GRID = [(b, s) for b in (1, 2) for s in (16, 32, 64, 128)]
# Draft-verify grid: (batch, k) where k is the *draft* count — the graph
# processes k+1 token positions per lane (last token + k drafts). Every
# decode batch size gets verify coverage so `serve --spec-k` never has
# to silently fall back to plain decode on the shipped artifacts
# (`blink info` warns when a manifest covers only a strict subset). The
# k-grid stays small: each k is a separately lowered graph, and the
# scheduler needs an exact-k match (a wider graph would verify drafts
# the lane never made).
DENSE_VERIFY_KS = [2, 4]
MOE_VERIFY_KS = [2, 4]

Graph = Tuple[str, str, int, int]  # (name, kind, batch, seq)


def graph_grid(moe: bool) -> List[Graph]:
    """The full graph list one export produces, in manifest order:
    decode graphs, then prefill, then the offset-prefill variants (which
    share the prefill grid — S is the padded *suffix* length, and the
    per-lane offsets are a runtime input), then the draft-verify grid
    (seq records k, the draft count; token input is [B, k+1])."""
    decode_batches = MOE_DECODE_BATCHES if moe else DENSE_DECODE_BATCHES
    prefill_grid = MOE_PREFILL_GRID if moe else DENSE_PREFILL_GRID
    verify_ks = MOE_VERIFY_KS if moe else DENSE_VERIFY_KS
    graphs: List[Graph] = [(f"decode_b{b}", "decode", b, 0) for b in decode_batches]
    graphs += [(f"prefill_b{b}_s{s}", "prefill", b, s) for b, s in prefill_grid]
    graphs += [
        (f"prefill_offset_b{b}_s{s}", "prefill_offset", b, s) for b, s in prefill_grid
    ]
    graphs += [
        (f"decode_verify_b{b}_k{k}", "decode_verify", b, k)
        for b in decode_batches
        for k in verify_ks
    ]
    return graphs


def manifest_text(cfg, graphs: List[Graph], backend: str) -> str:
    """The manifest the rust runtime parses, as one string.

    ``cfg`` is a ``model.ModelConfig`` (or anything shaped like one);
    ``backend`` records which attention build the graphs were lowered
    against ("pallas" kernels vs the jnp "ref" oracles) so the runtime
    can surface it in /metrics and eval output — older parsers ignore
    the extra token, newer ones default missing backends to
    "unspecified".
    """
    lines = ["blink-manifest v1", f"model {cfg.name}"]
    for field in (
        "vocab_size d_model n_layers n_heads n_kv_heads d_head d_ff "
        "block_size num_blocks max_blocks_per_seq n_experts top_k eos_token"
    ).split():
        lines.append(f"{field} {getattr(cfg, field)}")
    lines.append(f"moe {int(cfg.moe)}")
    lines.append(f"temperature {cfg.temperature}")
    lines.append(f"top_p {cfg.top_p}")
    lines.append(f"rope_theta {cfg.rope_theta}")
    for name, shape in cfg.param_specs():
        lines.append(f"param {name} {'x'.join(map(str, shape))} f32")
    for name, kind, b, s in graphs:
        lines.append(f"graph {name} {kind} {b} {s} {backend}")
    return "\n".join(lines) + "\n"
