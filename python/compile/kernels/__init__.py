"""L1: Pallas kernels for Blink's compute hot-spots (+ pure-jnp oracles).

All kernels lower with interpret=True so the AOT HLO runs on the CPU PJRT
client; see DESIGN.md §Hardware-Adaptation for the TPU mapping.
"""

from .flash_attention import flash_attention
from .moe_gating import moe_gating
from .paged_attention import paged_attention
from .paged_prefill import paged_prefill_attention
from .rmsnorm import rmsnorm
from .rope import rope
from .sampling import topp_sample
from . import ref

__all__ = [
    "flash_attention",
    "moe_gating",
    "paged_attention",
    "paged_prefill_attention",
    "rmsnorm",
    "rope",
    "topp_sample",
    "ref",
]
