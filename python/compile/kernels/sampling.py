"""Fused top-p (nucleus) sampling Pallas kernel.

The paper captures Top-P + temperature sampling *inside* each CUDA graph so
the forward pass through next-token selection is one device-side launch
(§4.2 "CUDA graph cache"). We mirror that: the sort (argsort, an XLA sort)
happens in the surrounding jax function, and this kernel fuses the
temperature scale → softmax → cumulative top-p filter → renormalize →
inverse-CDF draw into one VMEM pass over the sorted row.

Grid: (batch,). Input `uniform` is the externally supplied U[0,1) draw, so
the whole decode graph is a pure function of (state, seed) — required for
AOT export and for the rust runtime's determinism tests.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topp_kernel(sorted_logits_ref, u_ref, idx_ref, *, temperature: float, top_p: float):
    x = sorted_logits_ref[...].astype(jnp.float32)  # [V] descending
    v = x.shape[0]
    x = x / max(temperature, 1e-6)
    # Numerically-stable softmax over the sorted row.
    m = jnp.max(x)
    e = jnp.exp(x - m)
    probs = e / jnp.sum(e)
    cum = jnp.cumsum(probs)
    keep = (cum - probs) < top_p  # always keeps the argmax
    filt = jnp.where(keep, probs, 0.0)
    filt = filt / jnp.sum(filt)
    cdf = jnp.cumsum(filt)
    u = u_ref[0]
    idx = jnp.sum((cdf <= u).astype(jnp.int32))
    idx_ref[0] = jnp.clip(idx, 0, v - 1)


@functools.partial(
    jax.jit, static_argnames=("temperature", "top_p", "interpret")
)
def topp_sample(
    logits: jax.Array,
    uniform: jax.Array,
    temperature: float = 0.8,
    top_p: float = 0.95,
    interpret: bool = True,
) -> jax.Array:
    """logits: [B, V], uniform: [B] in [0,1). Returns token ids [B] int32."""
    b, v = logits.shape
    scaled = logits.astype(jnp.float32)
    order = jnp.argsort(-scaled, axis=-1)  # XLA sort outside the kernel
    sorted_logits = jnp.take_along_axis(scaled, order, axis=-1)

    idx_in_sorted = pl.pallas_call(
        functools.partial(_topp_kernel, temperature=temperature, top_p=top_p),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((None, v), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=interpret,
    )(sorted_logits, uniform)
    return jnp.take_along_axis(order, idx_in_sorted[:, None], axis=-1)[:, 0]
