"""Paged decode-attention Pallas kernel.

The paper's decode path reads K/V pages scattered through GPU memory via a
block table (PagedAttention). CUDA implementations gather pages with warp
loads; the TPU re-think (DESIGN.md §Hardware-Adaptation) keeps the pool in
HBM-like memory and walks the block table page-by-page with an in-kernel
fori_loop of dynamic-slice loads, online-softmax accumulation — so the
fast-memory working set is one page of K/V per sequence plus accumulators:

    VMEM footprint ≈ B * (Bs*Dh*2 (page K+V) + Hq*Dh*2 (q, acc)) floats.

Kernel structure (§Perf iteration 3): a **single program** vectorized over
(batch, kv_head, group) rather than a (batch, kv_head) grid. Decode is
bandwidth-bound with tiny per-program compute, so a grid buys no MXU
utilization but multiplies pool staging: under interpret=True each grid
step re-materializes its in-spec blocks, which made the original
(B × Hkv)-grid version copy the whole pool B×Hkv times per step (~50 ms
of the tiny model's decode step on CPU). One program stages the pool
once; on real TPU the same shape keeps the block-table walk as one
sequential DMA stream per page across all sequences.

interpret=True for CPU-PJRT execution; numerics must match
kernels.ref.paged_attention_ref.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _paged_kernel(q_ref, pool_ref, bt_ref, len_ref, o_ref, *, bs: int, max_blocks: int):
    # q_ref: [B, Hkv, G, Dh]; pool_ref: [N, 2, Hkv, Bs, Dh];
    # bt_ref: [B, max_blocks]; len_ref: [B]; o_ref: [B, Hkv, G, Dh].
    q = q_ref[...].astype(jnp.float32)
    b, hkv, g, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.array(dh, jnp.float32))
    seq_lens = len_ref[...]

    def body(j, carry):
        m_prev, l_prev, acc = carry
        blk = bt_ref[:, j]  # [B]
        kv = pool_ref[blk]  # [B, 2, Hkv, Bs, Dh] (gather of B pages)
        k = kv[:, 0].astype(jnp.float32)  # [B, Hkv, Bs, Dh]
        v = kv[:, 1].astype(jnp.float32)
        s = jnp.einsum("bhgd,bhsd->bhgs", q, k) * scale  # [B, Hkv, G, Bs]
        pos = j * bs + jax.lax.iota(jnp.int32, bs)  # [Bs]
        valid = pos[None, :] < seq_lens[:, None]  # [B, Bs]
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[..., None])
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhgs,bhsd->bhgd", p, v)
        return m_cur, l_cur, acc

    # Walk only pages that can contain valid tokens for the longest lane.
    n_blocks = jnp.minimum((jnp.max(seq_lens) + bs - 1) // bs, max_blocks)
    m0 = jnp.full((b, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, dh), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(
    q: jax.Array,
    kv_pool: jax.Array,
    block_tables: jax.Array,
    seq_lens: jax.Array,
    interpret: bool = True,
) -> jax.Array:
    """q: [B, Hq, Dh]; kv_pool: [N, 2, Hkv, Bs, Dh]; block_tables: [B, M];
    seq_lens: [B] (valid tokens incl. current). Returns [B, Hq, Dh]."""
    b, hq, dh = q.shape
    n, two, hkv, bs, _ = kv_pool.shape
    m = block_tables.shape[1]
    group = hq // hkv

    # [B, Hkv, group, Dh] so GQA groups share their kv head's pages.
    qg = q.reshape(b, hkv, group, dh)

    out = pl.pallas_call(
        functools.partial(_paged_kernel, bs=bs, max_blocks=m),
        grid=(),
        in_specs=[
            pl.BlockSpec(qg.shape, lambda: (0, 0, 0, 0)),
            pl.BlockSpec(kv_pool.shape, lambda: (0, 0, 0, 0, 0)),
            pl.BlockSpec((b, m), lambda: (0, 0)),
            pl.BlockSpec((b,), lambda: (0,)),
        ],
        out_specs=pl.BlockSpec(qg.shape, lambda: (0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, dh), q.dtype),
        interpret=interpret,
    )(qg, kv_pool, block_tables, seq_lens)
    return out.reshape(b, hq, dh)
