"""Pure-jnp oracles for every Pallas kernel (the CORE correctness signal).

Each ``*_ref`` here is the mathematical definition; the Pallas kernels in
this package must match these to tight tolerances (pytest + hypothesis
sweeps in python/tests/). The L2 model can be built against either
implementation (``use_pallas`` flag in model.py), which is how we A/B the
kernels inside the full lowered graph.
"""

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm over the last axis. x: [..., D], weight: [D]."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * weight).astype(x.dtype)


def rope_ref(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary position embedding.

    x: [..., T, H, Dh] (Dh even), positions: broadcastable to [..., T].
    Llama convention: rotate the two halves of the head dim.
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None, None] * freqs  # [..., T, 1, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    seq_lens: jax.Array,
    causal: bool = True,
) -> jax.Array:
    """Masked causal attention for (padded) prefill.

    q: [B, T, Hq, Dh], k/v: [B, T, Hkv, Dh] (GQA: Hq % Hkv == 0),
    seq_lens: [B] actual lengths; key positions >= seq_len are masked out.
    Returns [B, T, Hq, Dh].
    """
    b, t, hq, dh = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    scale = 1.0 / jnp.sqrt(jnp.array(dh, jnp.float32))
    kx = jnp.repeat(k, group, axis=2)
    vx = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kx.astype(jnp.float32)) * scale
    qi = jnp.arange(t)[:, None]
    ki = jnp.arange(t)[None, :]
    mask = ki <= qi if causal else jnp.ones((t, t), bool)
    valid = ki[None] < seq_lens[:, None, None]  # [B, 1, T] over the key axis
    full = mask[None, None] & valid[:, None]
    logits = jnp.where(full, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vx.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_attention_ref(
    q: jax.Array,
    kv_pool: jax.Array,
    block_tables: jax.Array,
    seq_lens: jax.Array,
) -> jax.Array:
    """Single-token decode attention over a paged KV pool.

    q: [B, Hq, Dh] — query for the current position of each sequence.
    kv_pool: [N, 2, Hkv, Bs, Dh] — global block pool (0=K, 1=V).
    block_tables: [B, M] int32 — block ids per sequence (padded, unused
        entries arbitrary but must be < N).
    seq_lens: [B] int32 — number of valid tokens per sequence (including
        the current one, whose K/V must already be written to the pool).
    Returns [B, Hq, Dh].
    """
    b, hq, dh = q.shape
    n, _, hkv, bs, _ = kv_pool.shape
    m = block_tables.shape[1]
    group = hq // hkv
    scale = 1.0 / jnp.sqrt(jnp.array(dh, jnp.float32))

    # Gather each sequence's logical KV: [B, M*Bs, Hkv, Dh]
    k = kv_pool[block_tables, 0]  # [B, M, Hkv, Bs, Dh]
    v = kv_pool[block_tables, 1]
    k = jnp.moveaxis(k, 3, 2).reshape(b, m * bs, hkv, dh)
    v = jnp.moveaxis(v, 3, 2).reshape(b, m * bs, hkv, dh)
    kx = jnp.repeat(k, group, axis=2)
    vx = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32), kx.astype(jnp.float32)) * scale
    pos = jnp.arange(m * bs)[None, :]
    valid = pos < seq_lens[:, None]
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", probs, vx.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_prefill_attention_ref(
    q: jax.Array,
    kv_pool: jax.Array,
    block_tables: jax.Array,
    offsets: jax.Array,
) -> jax.Array:
    """Suffix-prefill attention over the paged KV pool (offset graphs).

    q: [B, S, Hq, Dh] — queries for the *suffix* positions
        ``offsets[b] .. offsets[b] + S`` of each sequence.
    kv_pool: [N, 2, Hkv, Bs, Dh] — global block pool; the suffix's own
        K/V must already be written at its positions, and the cached
        prefix's K/V at positions ``0 .. offsets[b]``.
    block_tables: [B, M] int32 — block ids per sequence.
    offsets: [B] int32 — cached-prefix length per sequence (0 = cold,
        which reduces to ordinary causal prefill over the pool).
    Returns [B, S, Hq, Dh].

    Global causality: key position k is visible to suffix query i iff
    ``k <= offsets + i``. Padded table entries (block 0) sit at key
    positions beyond any valid query's horizon, so they are masked by
    the same bound.
    """
    b, s, hq, dh = q.shape
    n, _, hkv, bs, _ = kv_pool.shape
    m = block_tables.shape[1]
    group = hq // hkv
    scale = 1.0 / jnp.sqrt(jnp.array(dh, jnp.float32))

    k = kv_pool[block_tables, 0]  # [B, M, Hkv, Bs, Dh]
    v = kv_pool[block_tables, 1]
    k = jnp.moveaxis(k, 3, 2).reshape(b, m * bs, hkv, dh)
    v = jnp.moveaxis(v, 3, 2).reshape(b, m * bs, hkv, dh)
    kx = jnp.repeat(k, group, axis=2)
    vx = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kx.astype(jnp.float32)) * scale
    kpos = jnp.arange(m * bs)[None, None, :]  # [1, 1, K]
    qpos = offsets[:, None, None] + jnp.arange(s)[None, :, None]  # [B, S, 1]
    mask = kpos <= qpos  # [B, S, K]
    logits = jnp.where(mask[:, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vx.astype(jnp.float32))
    return out.astype(q.dtype)


def topp_sample_ref(
    logits: jax.Array,
    uniform: jax.Array,
    temperature: float = 0.8,
    top_p: float = 0.95,
) -> jax.Array:
    """Top-p (nucleus) sampling with temperature, driven by an external
    uniform draw (deterministic given the uniform — what the AOT graph uses).

    logits: [B, V], uniform: [B] in [0,1). Returns sampled token ids [B].
    """
    b, v = logits.shape
    scaled = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
    order = jnp.argsort(-scaled, axis=-1)
    sorted_logits = jnp.take_along_axis(scaled, order, axis=-1)
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # Keep tokens while the cumulative mass *before* them is < top_p
    # (always keeps the top token).
    keep = (cum - probs) < top_p
    filtered = jnp.where(keep, probs, 0.0)
    filtered = filtered / jnp.sum(filtered, axis=-1, keepdims=True)
    cdf = jnp.cumsum(filtered, axis=-1)
    idx_in_sorted = jnp.sum((cdf <= uniform[:, None]).astype(jnp.int32), axis=-1)
    idx_in_sorted = jnp.clip(idx_in_sorted, 0, v - 1)
    return jnp.take_along_axis(order, idx_in_sorted[:, None], axis=-1)[:, 0]


def moe_gating_ref(gate_logits: jax.Array, top_k: int = 2):
    """Softmax-normalized top-k routing weights.

    gate_logits: [T, E]. Returns (weights [T, E], indices [T, top_k]) where
    weights is dense over experts (zero off the top-k), renormalized over
    the selected experts — fixed shapes regardless of routing, as the
    paper's §6.2 MoE analysis requires.
    """
    t, e = gate_logits.shape
    topv, topi = jax.lax.top_k(gate_logits, top_k)
    w = jax.nn.softmax(topv.astype(jnp.float32), axis=-1)
    dense = jnp.zeros((t, e), jnp.float32)
    dense = dense.at[jnp.arange(t)[:, None], topi].set(w)
    return dense, topi


def swiglu_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    """SwiGLU MLP: (silu(x @ w_gate) * (x @ w_up)) @ w_down."""
    g = jax.nn.silu((x @ w_gate).astype(jnp.float32))
    return ((g * (x @ w_up)) @ w_down).astype(x.dtype)
