"""Flash-attention prefill Pallas kernel (causal, GQA, length-masked).

TPU mapping of the paper's CUDA attention (DESIGN.md §Hardware-Adaptation):
the CUDA version tiles Q over thread blocks and streams K/V through shared
memory; here the grid is (batch, q_head, q_block) and BlockSpec stages one
Q tile plus the full K/V row of the matching KV head into VMEM, with the
online-softmax accumulation walking K in chunks — the same HBM↔scratchpad
schedule expressed as an index map instead of threadblock logic. The inner
dot products are MXU-shaped ([bq, Dh] x [Dh, bk]).

interpret=True on CPU: numerics identical to kernels.ref.flash_attention_ref.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, bq: int, bk: int, t: int, causal: bool):
    # q_ref: [bq, Dh]; k_ref/v_ref: [T, Dh] (the full row for this kv head);
    # len_ref: [1] actual sequence length; o_ref: [bq, Dh].
    qb = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32)
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.array(dh, jnp.float32))
    seq_len = len_ref[0]

    q_pos = qb * bq + jax.lax.iota(jnp.int32, bq)

    def body(j, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (pl.dslice(j * bk, bk), slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(j * bk, bk), slice(None))).astype(jnp.float32)
        s = (q @ k.T) * scale  # [bq, bk]
        k_pos = j * bk + jax.lax.iota(jnp.int32, bk)
        mask = k_pos[None, :] < seq_len
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return m_cur, l_cur, acc

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, dh), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, t // bk, body, (m0, l0, acc0))
    # Padded query rows (q_pos >= seq_len) have fully-masked score rows;
    # l stays ~0 there. Guard the divide; their output is ignored upstream.
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    seq_lens: jax.Array,
    causal: bool = True,
    block_q: int = 32,
    block_k: int = 32,
    interpret: bool = True,
) -> jax.Array:
    """q: [B, T, Hq, Dh]; k/v: [B, T, Hkv, Dh]; seq_lens: [B]. -> [B, T, Hq, Dh]."""
    b, t, hq, dh = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    bq = min(block_q, t)
    bk = min(block_k, t)
    if t % bq != 0:
        bq = t
    if t % bk != 0:
        bk = t

    # Layout for clean BlockSpecs: q -> [B, Hq, T, Dh]; k/v -> [B, Hkv, T, Dh].
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)

    grid = (b, hq, t // bq)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, t=t, causal=causal),
        grid=grid,
        in_specs=[
            # `None` squeezes the singleton batch/head dims inside the kernel.
            pl.BlockSpec((None, None, bq, dh), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((None, None, t, dh), lambda bi, hi, qi: (bi, hi // group, 0, 0)),
            pl.BlockSpec((None, None, t, dh), lambda bi, hi, qi: (bi, hi // group, 0, 0)),
            pl.BlockSpec((1,), lambda bi, hi, qi: (bi,)),
        ],
        out_specs=pl.BlockSpec((None, None, bq, dh), lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, t, dh), q.dtype),
        interpret=interpret,
    )(qt, kt, vt, seq_lens)
    return jnp.moveaxis(out, 1, 2)
