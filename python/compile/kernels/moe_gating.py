"""MoE top-k gating Pallas kernel (fixed-shape routing).

The paper's MoE analysis (§6.2) leans on routing being *data-dependent but
not shape-dependent*: expert choice varies per token but every tensor keeps
a static shape, so the whole forward pass captures as one graph. This
kernel produces a dense [T, E] routing-weight matrix (zeros off the top-k)
via iterated masked argmax — no gather/scatter with dynamic shapes, so the
lowered HLO is branch-free and graph-capturable.

Grid: (token_blocks,). k is a compile-time constant (top-2 by default).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _gating_kernel(g_ref, w_ref, *, top_k: int):
    g = g_ref[...].astype(jnp.float32)  # [bt, E]
    bt, e = g.shape
    work = g
    sel_mask = jnp.zeros((bt, e), jnp.bool_)
    sel_vals = []
    for _ in range(top_k):  # top_k is tiny and static: unrolled
        mx = jnp.max(work, axis=-1, keepdims=True)
        pick = (work == mx) & ~sel_mask
        # Break ties toward the lowest expert index.
        first = jnp.cumsum(pick.astype(jnp.int32), axis=-1) == 1
        pick = pick & first
        sel_mask = sel_mask | pick
        sel_vals.append(mx[:, 0])
        work = jnp.where(pick, NEG_INF, work)
    # Softmax over the selected logits only, scattered back densely.
    vals = jnp.stack(sel_vals, axis=-1)  # [bt, k]
    m = jnp.max(vals, axis=-1, keepdims=True)
    ev = jnp.exp(vals - m)
    denom = jnp.sum(ev, axis=-1, keepdims=True)
    eg = jnp.exp(g - m)
    w_ref[...] = jnp.where(sel_mask, eg / denom, 0.0).astype(w_ref.dtype)


@functools.partial(jax.jit, static_argnames=("top_k", "block_rows", "interpret"))
def moe_gating(
    gate_logits: jax.Array,
    top_k: int = 2,
    block_rows: int = 16,
    interpret: bool = True,
) -> jax.Array:
    """gate_logits: [T, E] -> dense routing weights [T, E] (rows sum to 1)."""
    t, e = gate_logits.shape
    bt = min(block_rows, t)
    if t % bt != 0:
        bt = 1
    return pl.pallas_call(
        functools.partial(_gating_kernel, top_k=top_k),
        grid=(t // bt,),
        in_specs=[pl.BlockSpec((bt, e), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bt, e), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, e), jnp.float32),
        interpret=interpret,
    )(gate_logits)
