"""Rotary position embedding Pallas kernel.

One grid step per row-block of tokens; the sin/cos tables are computed
in-kernel from the position ids (no precomputed table in HBM), which on TPU
trades a few VPU transcendentals for an HBM stream — the right trade for
decode where T is tiny.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rope_kernel(x_ref, pos_ref, o_ref, *, theta: float):
    x = x_ref[...].astype(jnp.float32)  # [bt, H, Dh]
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = pos_ref[...].astype(jnp.float32)[:, None, None] * freqs  # [bt, 1, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    o_ref[...] = jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("theta", "block_rows", "interpret"))
def rope(
    x: jax.Array,
    positions: jax.Array,
    theta: float = 10000.0,
    block_rows: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """x: [T, H, Dh] (Dh even), positions: [T] int32. Returns same shape."""
    t, h, dh = x.shape
    bt = min(block_rows, t)
    if t % bt != 0:
        bt = 1
    grid = (t // bt,)
    return pl.pallas_call(
        functools.partial(_rope_kernel, theta=theta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, h, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bt, h, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, h, dh), x.dtype),
        interpret=interpret,
    )(x, positions)
