"""Paged suffix-prefill Pallas kernel (multi-token, offset graphs).

The offset-prefill graphs behind live prefix-cache hits and chunked
prefill (DESIGN.md §5/§7) compute attention for S *suffix* tokens per
lane at runtime global positions ``offsets[b] .. offsets[b] + S`` over
the paged KV pool — the cached prefix's K/V and the suffix's own K/V
both live in pool pages reached through the lane's block table. Until
this kernel existed the path composed a jnp gather/einsum
(``ref.paged_prefill_attention_ref``), which materializes every lane's
full [M*Bs, Hkv, Dh] K/V copy; this kernel streams the pool
page-by-page instead, the multi-token sibling of ``_paged_kernel``.

Kernel structure: a **single program** (grid=()) like the decode
kernel — offset prefill shares its constraint that a grid multiplies
pool staging under interpret=True (see paged_attention.py §Perf note) —
with two nested loops:

* an outer loop over Q tiles of ``block_q`` rows (bounds the score
  matrix to [B, Hkv, G, bq, Bs] like ``_flash_kernel``'s grid axis,
  with the same non-divisible fallback: ``S % bq != 0`` collapses to
  one S-row tile);
* an inner ``fori_loop`` walking block-table pages with
  dynamic-slice gathers and online-softmax accumulation.

Causal masking is at **true global positions**: pool position
``k = page*Bs + slot`` is visible to suffix row ``i`` of lane ``b``
iff ``k <= offsets[b] + i`` — exactly the oracle's rule, so padded
suffix rows (beyond the true suffix length) and padded block-table
entries (key positions beyond every row's horizon) mask identically
and numerics match the ref everywhere, not just on valid rows.

interpret=True for CPU-PJRT execution; numerics must match
kernels.ref.paged_prefill_attention_ref.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _paged_prefill_kernel(
    q_ref, pool_ref, bt_ref, off_ref, o_ref, *, bs: int, bq: int, max_blocks: int
):
    # q_ref/o_ref: [B, Hkv, G, S, Dh]; pool_ref: [N, 2, Hkv, Bs, Dh];
    # bt_ref: [B, max_blocks]; off_ref: [B].
    b, hkv, g, s, dh = q_ref.shape
    scale = 1.0 / jnp.sqrt(jnp.array(dh, jnp.float32))
    offsets = off_ref[...]  # [B]

    # Walk only pages that can hold keys inside some row's causal
    # horizon: the furthest query sits at global position
    # max(offsets) + S - 1.
    n_blocks = jnp.minimum((jnp.max(offsets) + s - 1) // bs + 1, max_blocks)

    def q_tile(qi, _):
        q = pl.load(
            q_ref,
            (slice(None), slice(None), slice(None), pl.dslice(qi * bq, bq), slice(None)),
        ).astype(jnp.float32)  # [B, Hkv, G, bq, Dh]
        # Global positions of this tile's suffix rows, per lane.
        q_pos = offsets[:, None] + qi * bq + jax.lax.iota(jnp.int32, bq)[None, :]

        def body(j, carry):
            m_prev, l_prev, acc = carry
            blk = bt_ref[:, j]  # [B]
            kv = pool_ref[blk]  # [B, 2, Hkv, Bs, Dh] (gather of B pages)
            k = kv[:, 0].astype(jnp.float32)  # [B, Hkv, Bs, Dh]
            v = kv[:, 1].astype(jnp.float32)
            sc = jnp.einsum("bhgqd,bhkd->bhgqk", q, k) * scale  # [B, Hkv, G, bq, Bs]
            k_pos = j * bs + jax.lax.iota(jnp.int32, bs)  # [Bs]
            mask = k_pos[None, None, :] <= q_pos[:, :, None]  # [B, bq, Bs]
            sc = jnp.where(mask[:, None, None, :, :], sc, NEG_INF)
            m_cur = jnp.maximum(m_prev, jnp.max(sc, axis=-1))
            alpha = jnp.exp(m_prev - m_cur)
            p = jnp.exp(sc - m_cur[..., None])
            l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bhkd->bhgqd", p, v)
            return m_cur, l_cur, acc

        m0 = jnp.full((b, hkv, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, bq), jnp.float32)
        acc0 = jnp.zeros((b, hkv, g, bq, dh), jnp.float32)
        _, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
        # Every row sees at least pool position 0 (offsets >= 0), so l
        # never collapses; the guard only protects against underflow.
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        pl.store(
            o_ref,
            (slice(None), slice(None), slice(None), pl.dslice(qi * bq, bq), slice(None)),
            out.astype(o_ref.dtype),
        )
        return 0

    jax.lax.fori_loop(0, s // bq, q_tile, 0)


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def paged_prefill_attention(
    q: jax.Array,
    kv_pool: jax.Array,
    block_tables: jax.Array,
    offsets: jax.Array,
    block_q: int = 32,
    interpret: bool = True,
) -> jax.Array:
    """q: [B, S, Hq, Dh] suffix queries; kv_pool: [N, 2, Hkv, Bs, Dh];
    block_tables: [B, M]; offsets: [B] cached-prefix lengths (0 = cold
    full prefill over the pool). Returns [B, S, Hq, Dh]."""
    b, s, hq, dh = q.shape
    n, two, hkv, bs, _ = kv_pool.shape
    m = block_tables.shape[1]
    group = hq // hkv

    bq = min(block_q, s)
    if s % bq != 0:
        bq = s

    # [B, Hkv, group, S, Dh] so GQA groups share their kv head's pages
    # (same head mapping as paged_attention: head h -> kv head h//group).
    qg = jnp.moveaxis(q.reshape(b, s, hkv, group, dh), 1, 3)

    out = pl.pallas_call(
        functools.partial(_paged_prefill_kernel, bs=bs, bq=bq, max_blocks=m),
        grid=(),
        in_specs=[
            pl.BlockSpec(qg.shape, lambda: (0, 0, 0, 0, 0)),
            pl.BlockSpec(kv_pool.shape, lambda: (0, 0, 0, 0, 0)),
            pl.BlockSpec((b, m), lambda: (0, 0)),
            pl.BlockSpec((b,), lambda: (0,)),
        ],
        out_specs=pl.BlockSpec(qg.shape, lambda: (0, 0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, s, dh), q.dtype),
        interpret=interpret,
    )(qg, kv_pool, block_tables, offsets)
    return jnp.moveaxis(out, 3, 1).reshape(b, s, hq, dh)
