"""Fused RMSNorm Pallas kernel.

TPU mapping (DESIGN.md §Hardware-Adaptation): rows are tiled into VMEM
blocks via BlockSpec; the reduction + scale fuse into one VPU pass instead
of the separate mean/rsqrt/mul HLO ops of the reference. interpret=True so
the lowering is plain HLO executable on the CPU PJRT client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 8


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(
    x: jax.Array,
    weight: jax.Array,
    eps: float = 1e-5,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """RMSNorm over the last axis. x: [T, D] (callers flatten), weight: [D]."""
    t, d = x.shape
    bt = min(block_rows, t)
    if t % bt != 0:
        bt = 1
    grid = (t // bt,)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=interpret,
    )(x, weight)
