"""Chunk-size cost curve: fused paged suffix-prefill kernel vs oracle.

The chunked-prefill scheduler piggybacks bounded `prefill_offset`
chunks on decode iterations; how many tokens ride free is set by the
chunk's cost curve (DESIGN.md §5, `CostModel::decode_step_with_chunk_s`
in rust/src/sim/costmodel.rs). This harness measures that curve: a
chunk-size sweep (S tokens per launch, fixed cached context) timing
`kernels.paged_prefill_attention` against the jnp gather/einsum oracle
it replaced, emitting a CSV with a fixed schema and seeded inputs —
row set, ordering, shapes and the numeric-agreement column are
deterministic; wall-clock columns are whatever this machine measures.

The fitted result (printed after the sweep) is the *relative* curve
the CostModel recalibration consumes: per-launch intercept + per-token
slope for each implementation, and the slope ratio oracle/kernel. The
interpret-mode numbers proxy composition overhead, not MXU throughput;
`Hardware::chunk_mxu_efficiency` documents how the ratio maps onto the
roofline constants.

Usage:
    python -m compile.bench_kernels [--out FILE] [--reps N]
    python -m compile.bench_kernels --smoke    # CI: 2 sizes, 1 rep,
                                               # asserts kernel==oracle
"""

import argparse
import sys
import time

import numpy as np

CONTEXT_TOKENS = 512
SWEEP_S = [32, 64, 128, 256, 512, 1024]
SMOKE_S = [32, 64]
CSV_HEADER = (
    "s_tokens,context_tokens,kernel_ms,ref_ms,"
    "kernel_us_per_token,ref_us_per_token,max_abs_err"
)


def _build_case(s: int, context: int, seed: int = 0):
    """One seeded suffix-prefill problem: TINY-like heads, bs=16 pages,
    the lane's table spanning context + S tokens of pool."""
    import jax.numpy as jnp

    hq, hkv, dh, bs = 8, 4, 32, 16
    m = (context + s + bs - 1) // bs
    n = m + 32
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, s, hq, dh)), jnp.float32)
    pool = jnp.asarray(rng.standard_normal((n, 2, hkv, bs, dh)), jnp.float32)
    bt = jnp.asarray(rng.permutation(n)[:m].reshape(1, m), jnp.int32)
    off = jnp.asarray([context], jnp.int32)
    return q, pool, bt, off


def _time_ms(fn, args, reps: int) -> float:
    """Best-of-reps wall time after a compile warmup."""
    import jax

    jax.block_until_ready(fn(*args))  # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return best


def run_sweep(sizes, reps: int, context: int = CONTEXT_TOKENS):
    """Returns (csv_text, rows) for the given chunk sizes."""
    from compile.kernels import paged_prefill_attention, ref

    rows = []
    for s in sizes:
        q, pool, bt, off = _build_case(s, context)
        got = np.asarray(paged_prefill_attention(q, pool, bt, off))
        want = np.asarray(ref.paged_prefill_attention_ref(q, pool, bt, off))
        err = float(np.max(np.abs(got - want)))
        k_ms = _time_ms(paged_prefill_attention, (q, pool, bt, off), reps)
        r_ms = _time_ms(ref.paged_prefill_attention_ref, (q, pool, bt, off), reps)
        rows.append((s, context, k_ms, r_ms, err))
    csv = CSV_HEADER + "\n"
    for s, ctx, k_ms, r_ms, err in rows:
        csv += (
            f"{s},{ctx},{k_ms:.3f},{r_ms:.3f},"
            f"{k_ms * 1e3 / s:.2f},{r_ms * 1e3 / s:.2f},{err:.2e}\n"
        )
    return csv, rows


def _fit_line(xs, ys):
    """Least-squares y ≈ a + b·x — (intercept, slope)."""
    x, y = np.asarray(xs, float), np.asarray(ys, float)
    b, a = np.polyfit(x, y, 1)
    return a, b


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=None, help="write the CSV here (default: stdout)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny sweep + kernel==oracle assertion (CI anti-rot check)",
    )
    args = ap.parse_args()

    sizes = SMOKE_S if args.smoke else SWEEP_S
    reps = 1 if args.smoke else args.reps
    csv, rows = run_sweep(sizes, reps)

    if args.smoke:
        worst = max(r[4] for r in rows)
        assert worst < 3e-4, f"kernel diverged from oracle: max_abs_err={worst}"
        print(csv, end="")
        print(f"smoke ok: {len(rows)} sizes, max_abs_err={worst:.2e}", file=sys.stderr)
        return 0

    if args.out:
        with open(args.out, "w") as f:
            f.write(csv)
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(csv, end="")

    ka, kb = _fit_line([r[0] for r in rows], [r[2] for r in rows])
    ra, rb = _fit_line([r[0] for r in rows], [r[3] for r in rows])
    print(
        f"fit kernel: {ka:.3f} ms + {kb * 1e3:.2f} us/token\n"
        f"fit oracle: {ra:.3f} ms + {rb * 1e3:.2f} us/token\n"
        f"per-token slope ratio oracle/kernel: {rb / kb:.2f}x "
        f"(feeds Hardware::chunk_mxu_efficiency, rust/src/sim/costmodel.rs)",
        file=sys.stderr,
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
