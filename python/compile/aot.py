"""AOT export: lower the L2 graphs to HLO text + weights npz + manifest.

Interchange is HLO *text*, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs under ``artifacts/<model>/``:
    manifest.txt           line-based manifest the rust runtime parses
    params.npz             weights (numpy savez; xla crate reads npz)
    decode_b{B}.hlo.txt    one decode graph per batch size in the grid
    prefill_b{B}_s{S}.hlo.txt
    prefill_offset_b{B}_s{S}.hlo.txt   suffix prefill at runtime offsets

This mirrors the paper's CUDA-graph cache (§4.2): a dense grid of
(batch, seq) executables captured once at startup, selected at runtime by
an O(1) tightest-fit lookup in rust/src/graphs/. The offset variants
(S = padded *suffix* length; per-lane block-aligned offsets are a runtime
[B] int32 input) are what let live prefix-cache hits prefill only the
uncached tail at the correct positions (DESIGN.md §7).

Run once via ``make artifacts``; never on the request path.
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .manifest import (
    DENSE_DECODE_BATCHES,
    DENSE_PREFILL_GRID,
    DENSE_VERIFY_KS,
    MOE_DECODE_BATCHES,
    MOE_PREFILL_GRID,
    MOE_VERIFY_KS,
    manifest_text,
)
from .model import TINY, TINY_MOE, ModelConfig, init_params, make_flat_fns


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _arg_specs(cfg: ModelConfig, batch: int, seq: int | None, offset: bool = False):
    """ShapeDtypeStructs in manifest order for one graph. Offset prefill
    graphs take an extra [B] int32 `offsets` input between tokens and
    seed (the per-lane block-aligned cached-prefix lengths)."""
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in cfg.param_specs()]
    kv = jax.ShapeDtypeStruct(
        (cfg.n_layers, cfg.num_blocks, 2, cfg.n_kv_heads, cfg.block_size, cfg.d_head),
        jnp.float32,
    )
    bt = jax.ShapeDtypeStruct((batch, cfg.max_blocks_per_seq), jnp.int32)
    sl = jax.ShapeDtypeStruct((batch,), jnp.int32)
    if seq is None:
        tok = jax.ShapeDtypeStruct((batch,), jnp.int32)
    else:
        tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    seed = jax.ShapeDtypeStruct((), jnp.uint32)
    out = specs + [kv, bt, sl, tok]
    if offset:
        out.append(jax.ShapeDtypeStruct((batch,), jnp.int32))
    out.append(seed)
    return out


def export_model(cfg: ModelConfig, out_root: str, use_pallas: bool = True) -> None:
    out = os.path.join(out_root, cfg.name)
    os.makedirs(out, exist_ok=True)
    t0 = time.time()

    params = init_params(cfg)
    np.savez(
        os.path.join(out, "params.npz"),
        **{k: np.asarray(v) for k, v in params.items()},
    )

    decode_fn, prefill_fn, prefill_offset_fn, decode_verify_fn = make_flat_fns(
        cfg, use_pallas=use_pallas
    )
    # Donate the KV pool (input -> output alias): the rust runtime swaps
    # the pool buffer each step anyway, and the alias lets XLA update it
    # in place instead of copying ~33 MB per decode step (§Perf: ~2x on
    # decode_b1). The alias survives the HLO-text interchange.
    kv_arg = len(cfg.param_specs())
    decode_batches = MOE_DECODE_BATCHES if cfg.moe else DENSE_DECODE_BATCHES
    prefill_grid = MOE_PREFILL_GRID if cfg.moe else DENSE_PREFILL_GRID
    verify_ks = MOE_VERIFY_KS if cfg.moe else DENSE_VERIFY_KS

    graphs = []  # (name, kind, batch, seq)
    for b in decode_batches:
        name = f"decode_b{b}"
        lowered = jax.jit(decode_fn, donate_argnums=(kv_arg,)).lower(*_arg_specs(cfg, b, None))
        with open(os.path.join(out, f"{name}.hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered))
        graphs.append((name, "decode", b, 0))
        print(f"  [{cfg.name}] {name} ({time.time() - t0:.1f}s)")
    for b, s in prefill_grid:
        name = f"prefill_b{b}_s{s}"
        lowered = jax.jit(prefill_fn, donate_argnums=(kv_arg,)).lower(*_arg_specs(cfg, b, s))
        with open(os.path.join(out, f"{name}.hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered))
        graphs.append((name, "prefill", b, s))
        print(f"  [{cfg.name}] {name} ({time.time() - t0:.1f}s)")
    # Offset prefill variants share the prefill grid: S is the padded
    # *suffix* length, and one graph serves every block-aligned hit
    # length because offsets are a runtime input.
    for b, s in prefill_grid:
        name = f"prefill_offset_b{b}_s{s}"
        lowered = jax.jit(prefill_offset_fn, donate_argnums=(kv_arg,)).lower(
            *_arg_specs(cfg, b, s, offset=True)
        )
        with open(os.path.join(out, f"{name}.hlo.txt"), "w") as f:
            f.write(to_hlo_text(lowered))
        graphs.append((name, "prefill_offset", b, s))
        print(f"  [{cfg.name}] {name} ({time.time() - t0:.1f}s)")
    # Draft-verify grid: seq in the manifest records k (the draft count);
    # the token input is [B, k+1] — the lane's pending last token plus k
    # drafts — and seq_lens doubles as the per-lane write offset, so no
    # extra runtime input is needed.
    for b in decode_batches:
        for k in verify_ks:
            name = f"decode_verify_b{b}_k{k}"
            lowered = jax.jit(decode_verify_fn, donate_argnums=(kv_arg,)).lower(
                *_arg_specs(cfg, b, k + 1)
            )
            with open(os.path.join(out, f"{name}.hlo.txt"), "w") as f:
                f.write(to_hlo_text(lowered))
            graphs.append((name, "decode_verify", b, k))
            print(f"  [{cfg.name}] {name} ({time.time() - t0:.1f}s)")

    backend = "pallas" if use_pallas else "ref"
    with open(os.path.join(out, "manifest.txt"), "w") as f:
        f.write(manifest_text(cfg, graphs, backend))
    print(f"[{cfg.name}] exported {len(graphs)} graphs in {time.time() - t0:.1f}s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="blink-tiny,blink-tiny-moe")
    ap.add_argument(
        "--no-pallas",
        action="store_true",
        help="lower against the jnp oracles instead of the Pallas kernels",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    wanted = set(args.models.split(","))
    for cfg in (TINY, TINY_MOE):
        if cfg.name in wanted:
            export_model(cfg, args.out, use_pallas=not args.no_pallas)


if __name__ == "__main__":
    main()
