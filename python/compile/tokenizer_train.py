"""Build-time BPE tokenizer trainer.

Trains a byte-level BPE vocabulary on a small bundled corpus and emits
``artifacts/vocab.blink`` — a flat text format the rust tokenizer
(`rust/src/tokenizer/`) parses without any JSON dependency:

    blink-vocab v1
    vocab_size <n>
    merges <m>
    TOKEN <id> <hex-bytes>          # one per token, id order
    MERGE <left-id> <right-id> <new-id> <rank>

Byte-level: ids 0..255 are the raw bytes; merged tokens follow. This is the
same construction family as GPT-2/llama BPE (greedy lowest-rank merge), so
the rust tokenizer's flat-hash merge table (paper §4.4, Fig 4) is exercised
exactly as in the paper.

Run once via ``make artifacts``; never on the request path.
"""

import argparse
import collections
import os

# A small English corpus, bundled so the build is hermetic (no downloads).
# Repetition with variation gives BPE enough statistics for ~2k merges.
_BASE_CORPUS = """
Large language model inference is rapidly becoming a core datacenter
service, yet current serving stacks keep the host processor on the critical
path for orchestration and token level control. This makes performance
sensitive to interference, undermining application colocation and forcing
operators to reserve headroom, leaving substantial capacity unutilized.
We introduce a serving architecture that removes the host from the steady
state inference path by redistributing responsibilities across a network
card and an accelerator. The system offloads request handling to the card,
which delivers inputs directly into device memory, and replaces host driven
scheduling with a persistent kernel that performs batching, scheduling, and
cache management without host involvement. The quick brown fox jumps over
the lazy dog while the five boxing wizards jump quickly. Pack my box with
five dozen liquor jugs. How vexingly quick daft zebras jump! Autoregressive
decoding transforms inference into a long lived, stateful process in which
each generated token depends on previously produced state. Latency
sensitive operations such as cache management, batching decisions, and
token streaming are tightly coupled to per token scheduling. As a result,
the control path becomes part of the critical loop. Existing systems
offload portions of request handling or data movement, but they do not
address autoregressive decoding. Token by token execution, placement, and
flow control repeatedly interact with device resident state, while
scheduling and coordination remain host centric. The scheduler executes an
infinite control loop: it scans the ring buffer for newly submitted
prompts, claims them via atomic compare and swap, selects and launches the
appropriate graph for prefill or decode, polls device resident output
buffers for completion after token sampling, and publishes generated tokens
and status updates back to the ring buffer. Numbers like 0 1 2 3 4 5 6 7 8
9 10 42 100 1024 2048 4096 and punctuation , . ; : ! ? ( ) [ ] { } " '
appear in real traffic, as do capitalized Words, ALLCAPS tokens, and
snake_case or camelCase identifiers common in code. def main(args): return
sum(x * x for x in range(10)) if args else None. The protocol parser on the
card validates requests, tokenizes prompts, locates a free ring buffer
slot, writes prompts into device memory, retrieves generated tokens,
detokenizes them, and streams responses back to clients over server sent
events. A window based recovery mechanism maintains a monotonically
increasing launch counter in shared memory and atomically replaces the
current graph execution with a fresh instance upon reaching the limit.
"""


def build_corpus() -> bytes:
    parts = [_BASE_CORPUS]
    # Vary casing and spacing so merges generalize a little.
    parts.append(_BASE_CORPUS.lower())
    parts.append(_BASE_CORPUS.upper()[: len(_BASE_CORPUS) // 4])
    parts.append(" ".join(w for w in _BASE_CORPUS.split()))
    return ("\n".join(parts)).encode("utf-8")


def train_bpe(corpus: bytes, vocab_size: int):
    """Greedy byte-level BPE. Returns (tokens: list[bytes], merges)."""
    tokens = [bytes([i]) for i in range(256)]
    merges = []  # (left_id, right_id, new_id)

    # Pre-tokenize on whitespace boundaries (merges never cross words),
    # mirroring GPT-2-style pretokenization and the rust tokenizer.
    words = collections.Counter()
    for w in corpus.split():
        words[b" " + w] += 1  # leading-space convention

    # word -> list of token ids
    word_syms = {w: list(w) for w in words}

    while len(tokens) < vocab_size:
        pair_counts = collections.Counter()
        for w, cnt in words.items():
            syms = word_syms[w]
            for a, b in zip(syms, syms[1:]):
                pair_counts[(a, b)] += cnt
        if not pair_counts:
            break
        (a, b), cnt = pair_counts.most_common(1)[0]
        if cnt < 2:
            break
        new_id = len(tokens)
        tokens.append(tokens[a] + tokens[b])
        merges.append((a, b, new_id))
        for w in words:
            syms = word_syms[w]
            out, i = [], 0
            while i < len(syms):
                if i + 1 < len(syms) and syms[i] == a and syms[i + 1] == b:
                    out.append(new_id)
                    i += 2
                else:
                    out.append(syms[i])
                    i += 1
            word_syms[w] = out
    return tokens, merges


def write_vocab(path: str, tokens, merges) -> None:
    with open(path, "w") as f:
        f.write("blink-vocab v1\n")
        f.write(f"vocab_size {len(tokens)}\n")
        f.write(f"merges {len(merges)}\n")
        for i, t in enumerate(tokens):
            f.write(f"TOKEN {i} {t.hex()}\n")
        for rank, (a, b, n) in enumerate(merges):
            f.write(f"MERGE {a} {b} {n} {rank}\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--vocab-size", type=int, default=2048)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    corpus = build_corpus()
    tokens, merges = train_bpe(corpus, args.vocab_size)
    out = os.path.join(args.out, "vocab.blink")
    write_vocab(out, tokens, merges)
    print(f"trained BPE: {len(tokens)} tokens, {len(merges)} merges -> {out}")


if __name__ == "__main__":
    main()
