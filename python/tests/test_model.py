"""L2 model semantics: pallas/oracle A-B equivalence inside the full
graph, KV-cache write placement, decode/prefill consistency, shapes."""

import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp

from compile.model import (
    TINY,
    TINY_MOE,
    decode_step,
    empty_kv_pool,
    init_params,
    make_flat_fns,
    prefill,
)

CFG = dataclasses.replace(TINY, n_layers=2, num_blocks=32, max_blocks_per_seq=4)
CFG_MOE = dataclasses.replace(
    TINY_MOE, n_layers=2, num_blocks=32, max_blocks_per_seq=4, d_ff=128
)


@pytest.fixture(scope="module")
def setup():
    params = init_params(CFG)
    kv = empty_kv_pool(CFG)
    bt = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], dtype=jnp.int32)
    tok = jnp.asarray(
        np.random.default_rng(0).integers(0, CFG.vocab_size, (2, 16)), dtype=jnp.int32
    )
    return params, kv, bt, tok


def test_prefill_pallas_matches_oracle(setup):
    params, kv, bt, tok = setup
    sl = jnp.asarray([10, 16], dtype=jnp.int32)
    t1, kv1 = prefill(params, kv, bt, sl, tok, jnp.uint32(1), CFG, use_pallas=True)
    t2, kv2 = prefill(params, kv, bt, sl, tok, jnp.uint32(1), CFG, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_allclose(np.asarray(kv1), np.asarray(kv2), rtol=3e-4, atol=3e-4)


def test_decode_pallas_matches_oracle(setup):
    params, kv, bt, tok = setup
    sl = jnp.asarray([10, 16], dtype=jnp.int32)
    _, kv1 = prefill(params, kv, bt, sl, tok, jnp.uint32(1), CFG, use_pallas=False)
    t = jnp.asarray([7, 9], dtype=jnp.int32)
    d1, kva = decode_step(params, kv1, bt, sl, t, jnp.uint32(2), CFG, use_pallas=True)
    d2, kvb = decode_step(params, kv1, bt, sl, t, jnp.uint32(2), CFG, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    np.testing.assert_allclose(np.asarray(kva), np.asarray(kvb), rtol=3e-4, atol=3e-4)


def test_decode_writes_kv_at_position(setup):
    params, kv, bt, tok = setup
    sl = jnp.asarray([10, 16], dtype=jnp.int32)
    _, kv1 = prefill(params, kv, bt, sl, tok, jnp.uint32(1), CFG, use_pallas=False)
    t = jnp.asarray([7, 9], dtype=jnp.int32)
    _, kv2 = decode_step(params, kv1, bt, sl, t, jnp.uint32(2), CFG, use_pallas=False)
    bs = CFG.block_size
    for b in range(2):
        pos = int(sl[b])
        blk = int(bt[b, pos // bs])
        slot = pos % bs
        assert not np.allclose(np.asarray(kv2)[0, blk, 0, :, slot, :], 0.0)


def test_prefill_respects_seq_len_padding(setup):
    """Changing tokens beyond seq_len must not change the sampled token."""
    params, kv, bt, tok = setup
    sl = jnp.asarray([10, 12], dtype=jnp.int32)
    t1, _ = prefill(params, kv, bt, sl, tok, jnp.uint32(3), CFG, use_pallas=False)
    tok2 = tok.at[:, 14:].set(0)
    t2, _ = prefill(params, kv, bt, sl, tok2, jnp.uint32(3), CFG, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_moe_model_runs_and_matches_oracle():
    params = init_params(CFG_MOE)
    kv = empty_kv_pool(CFG_MOE)
    bt = jnp.asarray([[1, 2, 3, 4]], dtype=jnp.int32)
    tok = jnp.asarray(
        np.random.default_rng(1).integers(0, CFG_MOE.vocab_size, (1, 16)), dtype=jnp.int32
    )
    sl = jnp.asarray([12], dtype=jnp.int32)
    t1, kv1 = prefill(params, kv, bt, sl, tok, jnp.uint32(4), CFG_MOE, use_pallas=True)
    t2, kv2 = prefill(params, kv, bt, sl, tok, jnp.uint32(4), CFG_MOE, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_allclose(np.asarray(kv1), np.asarray(kv2), rtol=3e-4, atol=3e-4)


def test_flat_fns_arg_order_matches_param_specs():
    decode_fn, prefill_fn = make_flat_fns(CFG, use_pallas=False)
    params = init_params(CFG)
    args = [params[n] for n, _ in CFG.param_specs()]
    kv = empty_kv_pool(CFG)
    bt = jnp.zeros((1, 4), jnp.int32).at[0, 0].set(1)
    sl = jnp.asarray([3], jnp.int32)
    tokd = jnp.asarray([5], jnp.int32)
    out, kv2 = decode_fn(*args, kv, bt, sl, tokd, jnp.uint32(0))
    assert out.shape == (1,)
    assert kv2.shape == kv.shape
    tokp = jnp.zeros((1, 16), jnp.int32)
    out, _ = prefill_fn(*args, kv, bt, sl, tokp, jnp.uint32(0))
    assert out.shape == (1,)


def test_param_count_reasonable():
    assert 2_000_000 < TINY.param_count() < 10_000_000
    assert TINY_MOE.param_count() > TINY.param_count() * 0.5
