"""L2 model semantics: pallas/oracle A-B equivalence inside the full
graph, KV-cache write placement, decode/prefill consistency, shapes."""

import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp

from compile.model import (
    TINY,
    TINY_MOE,
    decode_step,
    decode_verify,
    empty_kv_pool,
    init_params,
    make_flat_fns,
    prefill,
    prefill_offset,
)

CFG = dataclasses.replace(TINY, n_layers=2, num_blocks=32, max_blocks_per_seq=4)
CFG_MOE = dataclasses.replace(
    TINY_MOE, n_layers=2, num_blocks=32, max_blocks_per_seq=4, d_ff=128
)


@pytest.fixture(scope="module")
def setup():
    params = init_params(CFG)
    kv = empty_kv_pool(CFG)
    bt = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], dtype=jnp.int32)
    tok = jnp.asarray(
        np.random.default_rng(0).integers(0, CFG.vocab_size, (2, 16)), dtype=jnp.int32
    )
    return params, kv, bt, tok


def test_prefill_pallas_matches_oracle(setup):
    params, kv, bt, tok = setup
    sl = jnp.asarray([10, 16], dtype=jnp.int32)
    t1, kv1 = prefill(params, kv, bt, sl, tok, jnp.uint32(1), CFG, use_pallas=True)
    t2, kv2 = prefill(params, kv, bt, sl, tok, jnp.uint32(1), CFG, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_allclose(np.asarray(kv1), np.asarray(kv2), rtol=3e-4, atol=3e-4)


def test_decode_pallas_matches_oracle(setup):
    params, kv, bt, tok = setup
    sl = jnp.asarray([10, 16], dtype=jnp.int32)
    _, kv1 = prefill(params, kv, bt, sl, tok, jnp.uint32(1), CFG, use_pallas=False)
    t = jnp.asarray([7, 9], dtype=jnp.int32)
    d1, kva = decode_step(params, kv1, bt, sl, t, jnp.uint32(2), CFG, use_pallas=True)
    d2, kvb = decode_step(params, kv1, bt, sl, t, jnp.uint32(2), CFG, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    np.testing.assert_allclose(np.asarray(kva), np.asarray(kvb), rtol=3e-4, atol=3e-4)


def test_decode_writes_kv_at_position(setup):
    params, kv, bt, tok = setup
    sl = jnp.asarray([10, 16], dtype=jnp.int32)
    _, kv1 = prefill(params, kv, bt, sl, tok, jnp.uint32(1), CFG, use_pallas=False)
    t = jnp.asarray([7, 9], dtype=jnp.int32)
    _, kv2 = decode_step(params, kv1, bt, sl, t, jnp.uint32(2), CFG, use_pallas=False)
    bs = CFG.block_size
    for b in range(2):
        pos = int(sl[b])
        blk = int(bt[b, pos // bs])
        slot = pos % bs
        assert not np.allclose(np.asarray(kv2)[0, blk, 0, :, slot, :], 0.0)


def test_prefill_respects_seq_len_padding(setup):
    """Changing tokens beyond seq_len must not change the sampled token."""
    params, kv, bt, tok = setup
    sl = jnp.asarray([10, 12], dtype=jnp.int32)
    t1, _ = prefill(params, kv, bt, sl, tok, jnp.uint32(3), CFG, use_pallas=False)
    tok2 = tok.at[:, 14:].set(0)
    t2, _ = prefill(params, kv, bt, sl, tok2, jnp.uint32(3), CFG, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


@pytest.mark.parametrize("use_pallas", [False, True], ids=["ref", "pallas"])
def test_offset_prefill_matches_full_prefill(use_pallas):
    """The offset-graph numerics contract (DESIGN.md §7): for a prompt
    split at a block boundary, `prefill(prefix)` followed by
    `prefill_offset(suffix, offset)` must produce the same last-position
    logits as one full `prefill(prompt)` — rotary phases, KV write
    positions and causal masking all line up at the runtime offset."""
    params = init_params(CFG)
    bs = CFG.block_size
    rng = np.random.default_rng(7)
    for case, split_blocks in enumerate([1, 2]):
        length = 3 * bs  # 48 tokens over 3 blocks (max_blocks_per_seq = 4)
        split = split_blocks * bs
        prompt = jnp.asarray(
            rng.integers(0, CFG.vocab_size, (1, length)), dtype=jnp.int32
        )
        bt = jnp.asarray([[1, 2, 3, 4]], dtype=jnp.int32)
        seed = jnp.uint32(11 + case)

        full_logits, full_kv = prefill(
            params,
            empty_kv_pool(CFG),
            bt,
            jnp.asarray([length], jnp.int32),
            prompt,
            seed,
            CFG,
            use_pallas=use_pallas,
            return_logits=True,
        )
        # Turn 1: prefill the shared prefix alone (what indexed its blocks).
        _, kv1 = prefill(
            params,
            empty_kv_pool(CFG),
            bt,
            jnp.asarray([split], jnp.int32),
            prompt[:, :split],
            seed,
            CFG,
            use_pallas=use_pallas,
        )
        # Turn 2: offset prefill of only the uncached suffix.
        off_logits, off_kv = prefill_offset(
            params,
            kv1,
            bt,
            jnp.asarray([length], jnp.int32),
            prompt[:, split:],
            jnp.asarray([split], jnp.int32),
            seed,
            CFG,
            use_pallas=use_pallas,
            return_logits=True,
        )
        np.testing.assert_allclose(
            np.asarray(off_logits),
            np.asarray(full_logits),
            rtol=2e-3,
            atol=2e-3,
            err_msg=f"split={split}",
        )
        # The K/V written for the valid span must match the full prefill's
        # (blocks 1-3 hold positions 0..48; block 4 was never written).
        blocks = np.asarray(bt[0, :3])
        np.testing.assert_allclose(
            np.asarray(off_kv)[:, blocks],
            np.asarray(full_kv)[:, blocks],
            rtol=2e-3,
            atol=2e-3,
            err_msg=f"kv split={split}",
        )


@pytest.mark.parametrize("use_pallas", [False, True], ids=["ref", "pallas"])
def test_offset_prefill_batch_with_mixed_offsets(use_pallas):
    """One offset graph serves lanes with different (and zero) offsets:
    per-lane runtime offsets are the whole point of the [B] input. Runs
    against both attention backends — the pallas leg drives the fused
    paged suffix-prefill kernel through the full graph."""
    params = init_params(CFG)
    bs = CFG.block_size
    rng = np.random.default_rng(3)
    p0 = jnp.asarray(rng.integers(0, CFG.vocab_size, (1, 48)), dtype=jnp.int32)
    p1 = jnp.asarray(rng.integers(0, CFG.vocab_size, (1, 48)), dtype=jnp.int32)
    bt = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], dtype=jnp.int32)
    seed = jnp.uint32(5)

    # Lane 0: 2 cached blocks + 16-token suffix. Lane 1: cold (offset 0),
    # its "suffix" is the first 16 tokens of its prompt.
    _, kv1 = prefill(
        params,
        empty_kv_pool(CFG),
        bt[:1],
        jnp.asarray([2 * bs], jnp.int32),
        p0[:, : 2 * bs],
        seed,
        CFG,
        use_pallas=use_pallas,
    )
    toks = jnp.concatenate([p0[:, 2 * bs : 3 * bs], p1[:, :bs]], axis=0)
    logits, _ = prefill_offset(
        params,
        kv1,
        bt,
        jnp.asarray([48, 16], jnp.int32),
        toks,
        jnp.asarray([2 * bs, 0], jnp.int32),
        seed,
        CFG,
        use_pallas=use_pallas,
        return_logits=True,
    )
    want0, _ = prefill(
        params,
        empty_kv_pool(CFG),
        bt[:1],
        jnp.asarray([48], jnp.int32),
        p0,
        seed,
        CFG,
        use_pallas=use_pallas,
        return_logits=True,
    )
    want1, _ = prefill(
        params,
        empty_kv_pool(CFG),
        bt[1:],
        jnp.asarray([16], jnp.int32),
        p1[:, :bs],
        seed,
        CFG,
        use_pallas=use_pallas,
        return_logits=True,
    )
    np.testing.assert_allclose(np.asarray(logits[0]), np.asarray(want0[0]), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(logits[1]), np.asarray(want1[0]), rtol=2e-3, atol=2e-3)


def test_offset_prefill_pallas_matches_oracle_scrambled_blocks():
    """Direct A/B of the full offset-prefill graph on a *scrambled*
    block table: the kernel's page walk must agree with the oracle's
    gather when the lane's pages are physically non-contiguous."""
    params = init_params(CFG)
    bs = CFG.block_size
    rng = np.random.default_rng(21)
    prompt = jnp.asarray(rng.integers(0, CFG.vocab_size, (2, 48)), dtype=jnp.int32)
    # Non-contiguous, interleaved pages for both lanes (pool has 32).
    bt = jnp.asarray([[9, 3, 17, 25], [30, 7, 12, 1]], dtype=jnp.int32)
    seed = jnp.uint32(9)
    sl = jnp.asarray([48, 48], jnp.int32)
    _, kv1 = prefill(
        params, empty_kv_pool(CFG), bt, jnp.asarray([bs, bs], jnp.int32),
        prompt[:, :bs], seed, CFG, use_pallas=False,
    )
    args = (kv1, bt, sl, prompt[:, bs:], jnp.asarray([bs, bs], jnp.int32), seed, CFG)
    lp, kvp = prefill_offset(params, *args, use_pallas=True, return_logits=True)
    lr, kvr = prefill_offset(params, *args, use_pallas=False, return_logits=True)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lr), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(kvp), np.asarray(kvr), rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("use_pallas", [False, True], ids=["ref", "pallas"])
def test_decode_verify_s1_matches_decode_step(setup, use_pallas):
    """k = 0 degeneration: a 1-wide verify window IS a decode step —
    same flattened sampling stream, same pool write — so the scheduler's
    fallback from verify to plain decode can never change outputs."""
    params, kv, bt, tok = setup
    sl = jnp.asarray([10, 16], dtype=jnp.int32)
    _, kv1 = prefill(params, kv, bt, sl, tok, jnp.uint32(1), CFG, use_pallas=False)
    t = jnp.asarray([7, 9], dtype=jnp.int32)
    d, kva = decode_step(params, kv1, bt, sl, t, jnp.uint32(2), CFG, use_pallas=use_pallas)
    v, kvb = decode_verify(
        params, kv1, bt, sl, t[:, None], jnp.uint32(2), CFG, use_pallas=use_pallas
    )
    assert v.shape == (2, 1)
    np.testing.assert_array_equal(np.asarray(v[:, 0]), np.asarray(d))
    np.testing.assert_allclose(np.asarray(kva), np.asarray(kvb), rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("use_pallas", [False, True], ids=["ref", "pallas"])
def test_decode_verify_matches_sequential_decode_steps(use_pallas):
    """The draft-verify numerics contract: one k-wide verify launch fed
    the window [t0, d1, d2] must (a) write the same K/V at positions
    sl..sl+k that k+1 sequential `decode_step`s fed the same tokens
    would, and (b) produce per-position logits matching a 1-wide verify
    at each advanced position (which the test above pins to decode_step)
    — RoPE phases, causal masking and pool writes all line up at the
    true positions."""
    params = init_params(CFG)
    bt = jnp.asarray([[1, 2, 3, 4]], dtype=jnp.int32)
    prompt = jnp.asarray(
        np.random.default_rng(5).integers(0, CFG.vocab_size, (1, 16)), dtype=jnp.int32
    )
    sl0 = 12
    _, kv0 = prefill(
        params, empty_kv_pool(CFG), bt, jnp.asarray([sl0], jnp.int32), prompt,
        jnp.uint32(1), CFG, use_pallas=False,
    )
    window = jnp.asarray([[3, 11, 40]], dtype=jnp.int32)  # t0 + k=2 drafts

    logits, kv_ver = decode_verify(
        params, kv0, bt, jnp.asarray([sl0], jnp.int32), window, jnp.uint32(7), CFG,
        use_pallas=use_pallas, return_logits=True,
    )
    assert logits.shape == (1, 3, CFG.vocab_size)

    kv_seq = kv0
    for j in range(3):
        sl = jnp.asarray([sl0 + j], jnp.int32)
        lj, _ = decode_verify(
            params, kv_seq, bt, sl, window[:, j : j + 1], jnp.uint32(7), CFG,
            use_pallas=use_pallas, return_logits=True,
        )
        np.testing.assert_allclose(
            np.asarray(logits[:, j]), np.asarray(lj[:, 0]),
            rtol=2e-3, atol=2e-3, err_msg=f"pos {j}",
        )
        _, kv_seq = decode_step(
            params, kv_seq, bt, sl, window[:, j], jnp.uint32(7), CFG,
            use_pallas=use_pallas,
        )
    np.testing.assert_allclose(
        np.asarray(kv_ver), np.asarray(kv_seq), rtol=3e-4, atol=3e-4
    )


def test_decode_verify_pallas_matches_oracle(setup):
    params, kv, bt, tok = setup
    sl = jnp.asarray([10, 16], dtype=jnp.int32)
    _, kv1 = prefill(params, kv, bt, sl, tok, jnp.uint32(1), CFG, use_pallas=False)
    win = jnp.asarray([[7, 1, 5, 9, 2], [9, 3, 8, 4, 6]], dtype=jnp.int32)  # k=4
    v1, kva = decode_verify(params, kv1, bt, sl, win, jnp.uint32(2), CFG, use_pallas=True)
    v2, kvb = decode_verify(params, kv1, bt, sl, win, jnp.uint32(2), CFG, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_allclose(np.asarray(kva), np.asarray(kvb), rtol=3e-4, atol=3e-4)


def test_moe_model_runs_and_matches_oracle():
    params = init_params(CFG_MOE)
    kv = empty_kv_pool(CFG_MOE)
    bt = jnp.asarray([[1, 2, 3, 4]], dtype=jnp.int32)
    tok = jnp.asarray(
        np.random.default_rng(1).integers(0, CFG_MOE.vocab_size, (1, 16)), dtype=jnp.int32
    )
    sl = jnp.asarray([12], dtype=jnp.int32)
    t1, kv1 = prefill(params, kv, bt, sl, tok, jnp.uint32(4), CFG_MOE, use_pallas=True)
    t2, kv2 = prefill(params, kv, bt, sl, tok, jnp.uint32(4), CFG_MOE, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_allclose(np.asarray(kv1), np.asarray(kv2), rtol=3e-4, atol=3e-4)


def test_flat_fns_arg_order_matches_param_specs():
    decode_fn, prefill_fn, prefill_offset_fn, decode_verify_fn = make_flat_fns(
        CFG, use_pallas=False
    )
    params = init_params(CFG)
    args = [params[n] for n, _ in CFG.param_specs()]
    kv = empty_kv_pool(CFG)
    bt = jnp.zeros((1, 4), jnp.int32).at[0, 0].set(1)
    sl = jnp.asarray([3], jnp.int32)
    tokd = jnp.asarray([5], jnp.int32)
    out, kv2 = decode_fn(*args, kv, bt, sl, tokd, jnp.uint32(0))
    assert out.shape == (1,)
    assert kv2.shape == kv.shape
    tokp = jnp.zeros((1, 16), jnp.int32)
    out, _ = prefill_fn(*args, kv, bt, sl, tokp, jnp.uint32(0))
    assert out.shape == (1,)
    off = jnp.zeros((1,), jnp.int32)
    out, _ = prefill_offset_fn(*args, kv, bt, sl, tokp, off, jnp.uint32(0))
    assert out.shape == (1,)
    tokv = jnp.zeros((1, 3), jnp.int32)  # k = 2 drafts + last token
    out, _ = decode_verify_fn(*args, kv, bt, sl, tokv, jnp.uint32(0))
    assert out.shape == (1, 3)


def test_param_count_reasonable():
    assert 2_000_000 < TINY.param_count() < 10_000_000
    assert TINY_MOE.param_count() > TINY.param_count() * 0.5
