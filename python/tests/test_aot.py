"""AOT export: HLO text emission + manifest schema (small config so the
test stays fast; the full export is `make artifacts`)."""

import dataclasses
import os

import jax
import jax.numpy as jnp

from compile.aot import _arg_specs, to_hlo_text
from compile.model import TINY, make_flat_fns

CFG = dataclasses.replace(TINY, n_layers=1, num_blocks=8, max_blocks_per_seq=2)


def test_decode_graph_lowers_to_hlo_text():
    decode_fn, _, _, _ = make_flat_fns(CFG, use_pallas=True)
    lowered = jax.jit(decode_fn).lower(*_arg_specs(CFG, 2, None))
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # Tuple-rooted (tokens, kv) signature; 64-bit-id-free text form.
    assert "s32[2]" in text

def test_prefill_graph_lowers_to_hlo_text():
    _, prefill_fn, _, _ = make_flat_fns(CFG, use_pallas=True)
    lowered = jax.jit(prefill_fn).lower(*_arg_specs(CFG, 1, 16))
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "s32[1,16]" in text


def test_offset_prefill_graph_lowers_to_hlo_text():
    _, _, prefill_offset_fn, _ = make_flat_fns(CFG, use_pallas=True)
    lowered = jax.jit(prefill_offset_fn).lower(*_arg_specs(CFG, 1, 16, offset=True))
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "s32[1,16]" in text  # suffix tokens
    assert "s32[1]" in text  # runtime offsets (and seq_lens)


def test_decode_verify_graph_lowers_to_hlo_text():
    # k = 4 drafts -> the verify graph sees S = k+1 = 5 token positions.
    _, _, _, decode_verify_fn = make_flat_fns(CFG, use_pallas=True)
    lowered = jax.jit(decode_verify_fn).lower(*_arg_specs(CFG, 2, 5))
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "s32[2,5]" in text  # draft window tokens [B, k+1]


def test_arg_specs_match_manifest_order():
    specs = _arg_specs(CFG, 1, None)
    n_params = len(CFG.param_specs())
    assert len(specs) == n_params + 5  # params + kv + bt + sl + tok + seed
    kv = specs[n_params]
    assert kv.shape == (CFG.n_layers, CFG.num_blocks, 2, CFG.n_kv_heads, CFG.block_size, CFG.d_head)
    assert specs[-1].dtype == jnp.uint32


def test_offset_arg_specs_insert_offsets_before_seed():
    specs = _arg_specs(CFG, 2, 32, offset=True)
    n_params = len(CFG.param_specs())
    assert len(specs) == n_params + 6  # + offsets
    off = specs[-2]
    assert off.shape == (2,) and off.dtype == jnp.int32
    assert specs[-1].dtype == jnp.uint32
    assert specs[-3].shape == (2, 32)  # suffix tokens stay [B, S]
