"""The manifest contract, testable without JAX.

``compile.manifest`` is the jax-free half of the AOT exporter: the graph
grids and the manifest text the rust runtime parses. These tests pin the
blink-tiny-moe contract the interference eval and the rust MoE path rely
on — the manifest must declare the sparse geometry (``moe 1``,
``n_experts 4``, ``top_k 2``) and the MoE graph grid — so a grid or
field-order change that would strand the rust parser fails here, in any
environment, before an export ever runs.
"""

import dataclasses

import pytest

from compile.manifest import (
    MOE_DECODE_BATCHES,
    MOE_PREFILL_GRID,
    MOE_VERIFY_KS,
    graph_grid,
    manifest_text,
)


@dataclasses.dataclass(frozen=True)
class StubMoeConfig:
    """blink-tiny-moe's declared geometry (model.TINY_MOE), restated
    without importing the jax-backed model module. The jax-gated test
    below asserts this stub and the real config emit identical
    manifests, so the two cannot drift apart silently."""

    name: str = "blink-tiny-moe"
    vocab_size: int = 2048
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    n_kv_heads: int = 4
    d_head: int = 32
    d_ff: int = 512
    rope_theta: float = 10000.0
    block_size: int = 16
    num_blocks: int = 512
    max_blocks_per_seq: int = 32
    moe: bool = True
    n_experts: int = 4
    top_k: int = 2
    temperature: float = 0.8
    top_p: float = 0.95
    eos_token: int = 0

    def param_specs(self):
        l, d, f = self.n_layers, self.d_model, self.d_ff
        hq, hkv, dh = self.n_heads, self.n_kv_heads, self.d_head
        e = self.n_experts
        return [
            ("tok_embed", (self.vocab_size, d)),
            ("attn_norm", (l, d)),
            ("wq", (l, d, hq * dh)),
            ("wk", (l, d, hkv * dh)),
            ("wv", (l, d, hkv * dh)),
            ("wo", (l, hq * dh, d)),
            ("mlp_norm", (l, d)),
            ("router", (l, d, e)),
            ("w_gate", (l, e, d, f)),
            ("w_up", (l, e, d, f)),
            ("w_down", (l, e, f, d)),
            ("final_norm", (d,)),
        ]


def test_moe_manifest_declares_sparse_geometry():
    text = manifest_text(StubMoeConfig(), graph_grid(moe=True), "pallas")
    lines = text.splitlines()
    assert lines[0] == "blink-manifest v1"
    assert lines[1] == "model blink-tiny-moe"
    assert "moe 1" in lines
    assert "n_experts 4" in lines
    assert "top_k 2" in lines
    # Expert weights carry the [L, E, ...] axis the rust loader expects.
    assert "param router 4x256x4 f32" in lines
    assert "param w_gate 4x4x256x512 f32" in lines


def test_moe_graph_grid_covers_decode_and_both_prefill_kinds():
    graphs = graph_grid(moe=True)
    names = [g[0] for g in graphs]
    for b in MOE_DECODE_BATCHES:
        assert f"decode_b{b}" in names
    for b, s in MOE_PREFILL_GRID:
        assert f"prefill_b{b}_s{s}" in names
        assert f"prefill_offset_b{b}_s{s}" in names
    # Verify coverage spans the *full* decode batch grid, so `serve
    # --spec-k` never silently falls back on the shipped artifacts.
    for b in MOE_DECODE_BATCHES:
        for k in MOE_VERIFY_KS:
            assert f"decode_verify_b{b}_k{k}" in names
    assert len(names) == len(set(names)) == len(MOE_DECODE_BATCHES) + 2 * len(
        MOE_PREFILL_GRID
    ) + len(MOE_DECODE_BATCHES) * len(MOE_VERIFY_KS)
    # Every graph line lands in the manifest with the backend token.
    text = manifest_text(StubMoeConfig(), graphs, "ref")
    assert f"graph decode_b{MOE_DECODE_BATCHES[0]} decode {MOE_DECODE_BATCHES[0]} 0 ref" in text
    # seq records k (the draft count), not the k+1 token width.
    assert "graph decode_verify_b1_k2 decode_verify 1 2 ref" in text
    assert all(f"graph {n} " in text for n in names)


def test_stub_matches_the_real_model_config():
    jax = pytest.importorskip("jax")  # noqa: F841 — model.py imports jax
    from compile.model import TINY_MOE

    assert manifest_text(StubMoeConfig(), graph_grid(moe=True), "pallas") == manifest_text(
        TINY_MOE, graph_grid(moe=True), "pallas"
    )
