"""BPE trainer invariants + vocab file format."""

import os

from compile.tokenizer_train import build_corpus, train_bpe, write_vocab


def test_trained_vocab_structure(tmp_path):
    corpus = build_corpus()
    tokens, merges = train_bpe(corpus, 512)
    assert len(tokens) <= 512
    assert len(tokens) == 256 + len(merges)
    # Byte tokens intact.
    for i in range(256):
        assert tokens[i] == bytes([i])
    # Every merge produces the concatenation of its parts.
    for a, b, n in merges:
        assert tokens[n] == tokens[a] + tokens[b]
    # Ranks are creation-ordered (new ids ascending).
    ids = [n for _, _, n in merges]
    assert ids == sorted(ids)

    out = tmp_path / "vocab.blink"
    write_vocab(str(out), tokens, merges)
    text = out.read_text()
    assert text.startswith("blink-vocab v1\n")
    assert text.count("TOKEN ") == len(tokens)
    assert text.count("MERGE ") == len(merges)


def test_common_words_become_single_tokens():
    corpus = build_corpus()
    tokens, merges = train_bpe(corpus, 2048)
    token_set = set(tokens)
    assert b" the" in token_set, "highest-frequency word must merge fully"
