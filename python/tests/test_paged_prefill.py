"""Kernel-vs-oracle sweeps for the fused paged suffix-prefill kernel.

Seeded parametrized cases (deliberately not hypothesis-driven, so they
run — never skip — wherever jax is present) covering the shapes the
offset graphs actually launch: scrambled non-contiguous block tables,
mixed per-lane offsets in one batch, padded lanes whose true suffix is
shorter than the padded S, and a non-divisible ``S % block_q`` shape
that pins the block-size fallback path ``flash_attention`` also relies
on. Tolerances match the attention-kernel bar in test_kernel.py (3e-4).
"""

import numpy as np
import pytest
import jax.numpy as jnp

from compile.kernels import paged_prefill_attention, ref

pytestmark = pytest.mark.kernel

TOL = dict(rtol=3e-4, atol=3e-4)


def _case(seed, b, s, hq, hkv, dh, bs, n, m, offsets, scrambled=True):
    """Build one random (q, pool, block_tables, offsets) problem.

    Block tables draw non-overlapping pages from a permutation of the
    pool (scrambled: physically non-contiguous, like a pool that has
    churned through alloc/free cycles); sequential tables cover the
    fresh-pool layout.
    """
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, s, hq, dh)), jnp.float32)
    pool = jnp.asarray(rng.standard_normal((n, 2, hkv, bs, dh)), jnp.float32)
    if scrambled:
        pages = rng.permutation(n)[: b * m]
    else:
        pages = np.arange(b * m)
    bt = jnp.asarray(pages.reshape(b, m), jnp.int32)
    off = jnp.asarray(offsets, jnp.int32)
    return q, pool, bt, off


def _assert_matches_ref(q, pool, bt, off, **kw):
    got = paged_prefill_attention(q, pool, bt, off, **kw)
    want = ref.paged_prefill_attention_ref(q, pool, bt, off)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("seed,b,s", [(0, 1, 16), (1, 2, 32), (2, 4, 16), (3, 2, 64)])
def test_matches_ref_scrambled_block_tables(seed, b, s):
    """Pool pages reached through permuted, non-contiguous block tables."""
    q, pool, bt, off = _case(
        seed, b=b, s=s, hq=8, hkv=4, dh=16, bs=16, n=64, m=8,
        offsets=[16 * (i % 3) for i in range(b)],
    )
    _assert_matches_ref(q, pool, bt, off)


def test_matches_ref_sequential_block_tables():
    """The fresh-pool identity layout is not a special case."""
    q, pool, bt, off = _case(
        7, b=2, s=32, hq=8, hkv=4, dh=16, bs=16, n=64, m=6,
        offsets=[32, 0], scrambled=False,
    )
    _assert_matches_ref(q, pool, bt, off)


def test_mixed_offsets_in_one_batch():
    """One launch serves lanes at different (and zero) offsets — the
    whole point of the runtime [B] offsets input."""
    q, pool, bt, off = _case(
        11, b=4, s=16, hq=8, hkv=4, dh=16, bs=16, n=64, m=8,
        offsets=[0, 16, 48, 96],
    )
    _assert_matches_ref(q, pool, bt, off)


def test_padded_lanes_match_ref_on_all_rows():
    """seq_len < padded S: rows past the true suffix are padding, but the
    kernel must still match the oracle on *every* row (the model slices
    the last valid row out of x, so padded rows feed nothing — matching
    the ref everywhere is the strongest and simplest contract)."""
    b, s, bs = 2, 32, 16
    q, pool, bt, off = _case(
        13, b=b, s=s, hq=8, hkv=4, dh=16, bs=bs, n=64, m=8, offsets=[32, 0],
    )
    # True suffix lengths 20 and 9 (< padded 32): scramble the padding
    # rows' queries to prove they don't perturb valid rows either way.
    rng = np.random.default_rng(99)
    q_scrambled = np.asarray(q).copy()
    q_scrambled[0, 20:] = rng.standard_normal(q_scrambled[0, 20:].shape)
    q_scrambled[1, 9:] = rng.standard_normal(q_scrambled[1, 9:].shape)
    q_scrambled = jnp.asarray(q_scrambled)
    _assert_matches_ref(q_scrambled, pool, bt, off)
    got = paged_prefill_attention(q, pool, bt, off)
    got_s = paged_prefill_attention(q_scrambled, pool, bt, off)
    np.testing.assert_allclose(
        np.asarray(got)[0, :20], np.asarray(got_s)[0, :20], **TOL
    )
    np.testing.assert_allclose(np.asarray(got)[1, :9], np.asarray(got_s)[1, :9], **TOL)


def test_non_divisible_block_q_falls_back_to_full_tile():
    """S % block_q != 0 pins the block-size fallback (bq -> S), the same
    path flash_attention relies on for odd padded lengths."""
    q, pool, bt, off = _case(
        17, b=2, s=24, hq=8, hkv=4, dh=16, bs=8, n=64, m=12, offsets=[8, 0],
    )
    _assert_matches_ref(q, pool, bt, off, block_q=16)  # 24 % 16 != 0
    _assert_matches_ref(q, pool, bt, off, block_q=8)  # divisible tiling too


def test_garbage_in_padded_table_entries_is_masked():
    """Block-table entries past the causal horizon may point anywhere in
    the pool (the rust allocator leaves stale ids there); the global
    position bound masks them, so output must not change."""
    q, pool, bt, off = _case(
        19, b=2, s=16, hq=8, hkv=4, dh=16, bs=16, n=64, m=8, offsets=[16, 0],
    )
    # Horizon: max row position = off + s - 1 < 2 pages (lane 0) / 1 page
    # (lane 1). Entries from page index 3 on are dead for both lanes.
    bt_garbage = np.asarray(bt).copy()
    bt_garbage[:, 3:] = np.random.default_rng(5).integers(0, 64, bt_garbage[:, 3:].shape)
    got = paged_prefill_attention(q, pool, bt, off)
    got_g = paged_prefill_attention(q, pool, jnp.asarray(bt_garbage, jnp.int32), off)
    np.testing.assert_allclose(np.asarray(got), np.asarray(got_g), **TOL)


def test_gqa_group_head_mapping():
    """Hq == Hkv (group 1) and Hq = 2*Hkv map heads exactly like the ref
    (head h reads kv head h // group)."""
    for hq, hkv, seed in [(4, 4, 23), (8, 4, 29)]:
        q, pool, bt, off = _case(
            seed, b=2, s=16, hq=hq, hkv=hkv, dh=16, bs=16, n=32, m=4,
            offsets=[16, 0],
        )
        _assert_matches_ref(q, pool, bt, off)
