"""Kernel-vs-oracle correctness: the CORE L1 signal.

Hypothesis sweeps shapes/dtypes per the session contract; every Pallas
kernel must match its pure-jnp oracle in kernels/ref.py to tight
tolerances under interpret=True.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import (
    flash_attention,
    moe_gating,
    paged_attention,
    rmsnorm,
    rope,
    topp_sample,
)
from compile.kernels.ref import (
    flash_attention_ref,
    moe_gating_ref,
    paged_attention_ref,
    rmsnorm_ref,
    rope_ref,
    topp_sample_ref,
)

SETTINGS = dict(max_examples=25, deadline=None)


def arr(rng, shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


@settings(**SETTINGS)
@given(
    t=st.integers(1, 48),
    d=st.sampled_from([16, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rmsnorm_matches_ref(t, d, seed):
    rng = np.random.default_rng(seed)
    x, w = arr(rng, (t, d), 3.0), arr(rng, (d,))
    np.testing.assert_allclose(rmsnorm(x, w), rmsnorm_ref(x, w), rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(
    t=st.integers(1, 32),
    h=st.sampled_from([1, 4, 8]),
    dh=st.sampled_from([8, 32, 64]),
    offset=st.integers(0, 500),
    seed=st.integers(0, 2**31 - 1),
)
def test_rope_matches_ref(t, h, dh, offset, seed):
    rng = np.random.default_rng(seed)
    x = arr(rng, (t, h, dh))
    pos = jnp.arange(offset, offset + t, dtype=jnp.int32)
    np.testing.assert_allclose(rope(x, pos), rope_ref(x, pos), rtol=2e-4, atol=2e-4)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    t=st.sampled_from([16, 32, 64, 128]),
    heads=st.sampled_from([(4, 4), (8, 4), (8, 2)]),
    dh=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_matches_ref(b, t, heads, dh, seed):
    hq, hkv = heads
    rng = np.random.default_rng(seed)
    q = arr(rng, (b, t, hq, dh))
    k = arr(rng, (b, t, hkv, dh))
    v = arr(rng, (b, t, hkv, dh))
    lens = jnp.asarray(rng.integers(1, t + 1, b), dtype=jnp.int32)
    got = flash_attention(q, k, v, lens)
    want = flash_attention_ref(q, k, v, lens)
    # Only rows < seq_len are consumed downstream; compare those.
    for i in range(b):
        n = int(lens[i])
        np.testing.assert_allclose(got[i, :n], want[i, :n], rtol=3e-4, atol=3e-4)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 4),
    heads=st.sampled_from([(4, 4), (8, 4)]),
    dh=st.sampled_from([16, 32]),
    bs=st.sampled_from([8, 16]),
    m=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_paged_attention_matches_ref(b, heads, dh, bs, m, seed):
    hq, hkv = heads
    rng = np.random.default_rng(seed)
    n_blocks = 32
    q = arr(rng, (b, hq, dh))
    pool = arr(rng, (n_blocks, 2, hkv, bs, dh))
    bt = jnp.asarray(rng.integers(0, n_blocks, (b, m)), dtype=jnp.int32)
    lens = jnp.asarray(rng.integers(1, m * bs + 1, b), dtype=jnp.int32)
    got = paged_attention(q, pool, bt, lens)
    want = paged_attention_ref(q, pool, bt, lens)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 4),
    v=st.sampled_from([32, 256, 2048]),
    temp=st.floats(0.2, 1.5),
    top_p=st.floats(0.5, 0.99),
    seed=st.integers(0, 2**31 - 1),
)
def test_topp_sampling_matches_ref(b, v, temp, top_p, seed):
    rng = np.random.default_rng(seed)
    logits = arr(rng, (b, v), 3.0)
    u = jnp.asarray(rng.random(b, dtype=np.float32))
    got = topp_sample(logits, u, temperature=temp, top_p=top_p)
    want = topp_sample_ref(logits, u, temperature=temp, top_p=top_p)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(**SETTINGS)
@given(
    t=st.integers(1, 40),
    e=st.sampled_from([4, 8, 16]),
    k=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_moe_gating_matches_ref(t, e, k, seed):
    rng = np.random.default_rng(seed)
    g = arr(rng, (t, e), 2.0)
    got = moe_gating(g, top_k=k)
    want, _ = moe_gating_ref(g, top_k=k)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    # Invariants: rows sum to 1, exactly k nonzeros.
    np.testing.assert_allclose(np.asarray(got).sum(-1), 1.0, rtol=1e-5)
    assert ((np.asarray(got) > 0).sum(-1) == k).all()


def test_sampling_always_keeps_argmax():
    # top_p tiny -> greedy.
    logits = jnp.asarray([[0.1, 5.0, -2.0, 1.0]], dtype=jnp.float32)
    for u in [0.0, 0.5, 0.999]:
        tok = topp_sample(logits, jnp.asarray([u], dtype=jnp.float32), top_p=0.01)
        assert int(tok[0]) == 1


def test_paged_attention_ignores_padded_blocks():
    # Garbage in unused block-table entries must not change the output.
    rng = np.random.default_rng(0)
    b, hq, hkv, dh, bs, m, n = 2, 4, 4, 16, 8, 4, 16
    q = arr(rng, (b, hq, dh))
    pool = arr(rng, (n, 2, hkv, bs, dh))
    bt1 = jnp.asarray(rng.integers(0, n, (b, m)), dtype=jnp.int32)
    lens = jnp.asarray([5, 9], dtype=jnp.int32)  # only block 0/1 valid
    bt2 = bt1.at[:, 2:].set(jnp.asarray(rng.integers(0, n, (b, 2)), dtype=jnp.int32))
    np.testing.assert_allclose(
        paged_attention(q, pool, bt1, lens), paged_attention(q, pool, bt2, lens), rtol=1e-6
    )
