import importlib.util
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

# Dependency-gated collection so `pytest python/tests` passes everywhere
# (CI and minimal containers alike): JAX-dependent test modules skip
# cleanly when jax is absent, and the hypothesis sweeps skip when
# hypothesis is absent. The tokenizer tests are dependency-free.
collect_ignore = []
_HAVE_JAX = importlib.util.find_spec("jax") is not None
_HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None
if not _HAVE_JAX:
    collect_ignore += ["test_aot.py", "test_kernel.py", "test_model.py"]
if not _HAVE_HYPOTHESIS:
    collect_ignore += ["test_kernel.py"]
