import importlib.util
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

# Dependency-gated collection so `pytest python/tests` passes everywhere
# (CI and minimal containers alike): JAX-dependent test modules skip
# cleanly when jax is absent, and the hypothesis sweeps skip when
# hypothesis is absent. The tokenizer tests are dependency-free.
collect_ignore = []
_HAVE_JAX = importlib.util.find_spec("jax") is not None
_HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None
if not _HAVE_JAX:
    collect_ignore += [
        "test_aot.py",
        "test_kernel.py",
        "test_model.py",
        "test_paged_prefill.py",
    ]
if not _HAVE_HYPOTHESIS:
    collect_ignore += ["test_kernel.py"]


def pytest_configure(config):
    # The interpret-mode kernel sweeps are marker-tagged so constrained
    # environments can deselect them (`-m "not kernel"`) without
    # touching the jax gate above.
    config.addinivalue_line(
        "markers", "kernel: interpret-mode Pallas kernel-vs-oracle sweeps"
    )
