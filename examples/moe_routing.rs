//! MoE serving demo: the blink-tiny-moe model end to end, demonstrating
//! the paper's §6.2 observation that MoE routing is data-dependent but
//! *shape*-static — the persistent scheduler launches MoE decode graphs
//! exactly like dense ones, with zero host involvement in expert routing
//! (the gating top-k runs inside the AOT graph; see
//! python/compile/kernels/moe_gating.py).
//!
//!     cargo run --release --example moe_routing

use blink::gpu::Placement;
use blink::server::{BlinkServer, ServerConfig};

fn main() -> anyhow::Result<()> {
    println!("[moe] starting Blink on blink-tiny-moe (AOT compile ~30s)...");
    let server = BlinkServer::start(ServerConfig {
        model: "blink-tiny-moe".into(),
        placement: Placement::GpuResident,
        ..Default::default()
    })?;
    let m = &server.manifest;
    println!(
        "[moe] model={} experts={} top_k={} layers={} (moe={})",
        m.model, m.n_experts, m.top_k, m.n_layers, m.moe
    );

    // A small batch of concurrent requests: routing differs per token but
    // every launch uses the same fixed-shape graphs from the cache.
    let prompts = [
        "the scheduler claims pending prompts via atomic compare and swap",
        "tokens stream back to clients over server sent events",
        "expert routing is data dependent but not shape dependent",
        "the ring buffer is the only shared data structure",
    ];
    let handles: Vec<_> =
        prompts.iter().map(|p| server.submit_text(p, 16).expect("submit")).collect();
    for (p, h) in prompts.iter().zip(handles) {
        let toks = h.collect().map_err(|e| anyhow::anyhow!(e))?;
        let text = blink::tokenizer::decode(&server.frontend.vocab, &toks);
        println!("[moe] {:>2} tokens for {:?}\n      -> {:?}", toks.len(), p, text);
    }
    println!("[moe] scheduler: {}", server.scheduler.stats.summary());
    println!("[moe] no host round-trip occurred for any routing decision:");
    println!("      gating top-k executes inside each decode graph (L1 Pallas kernel).");
    server.shutdown();
    Ok(())
}
