//! MoE serving demo: the blink-tiny-moe model end to end, demonstrating
//! the paper's §6.2 observation that MoE routing is data-dependent but
//! *shape*-static — the persistent scheduler launches MoE decode graphs
//! exactly like dense ones, with zero host involvement in expert routing
//! (the gating top-k runs inside the AOT graph; see
//! python/compile/kernels/moe_gating.py).
//!
//! Without compiled artifacts the demo falls back to the modeled
//! executor on the `modeled-tiny-moe` manifest: the scheduler pipeline
//! is identical, and each decode step pays the manifest-declared
//! expert-dispatch cost for the batch's expected expert union.
//!
//!     cargo run --release --example moe_routing

use std::sync::Arc;

use blink::eval::live::modeled_moe_manifest;
use blink::gpu::{
    executor::expected_active_experts, Executor, ModeledCost, Placement, Scheduler,
    SchedulerConfig,
};
use blink::ringbuf::{RingBuffer, RingConfig, SlotState};
use blink::server::{BlinkServer, ServerConfig};
use blink::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    println!("[moe] starting Blink on blink-tiny-moe (AOT compile ~30s)...");
    match BlinkServer::start(ServerConfig {
        model: "blink-tiny-moe".into(),
        placement: Placement::GpuResident,
        ..Default::default()
    }) {
        Ok(server) => run_compiled(server),
        Err(e) => {
            println!("[moe] no compiled artifacts ({e:#}); falling back to the modeled executor");
            run_modeled()
        }
    }
}

fn run_compiled(server: BlinkServer) -> anyhow::Result<()> {
    let m = &server.manifest;
    println!(
        "[moe] model={} experts={} top_k={} layers={} (moe={})",
        m.model, m.n_experts, m.top_k, m.n_layers, m.moe
    );

    // A small batch of concurrent requests: routing differs per token but
    // every launch uses the same fixed-shape graphs from the cache.
    let prompts = [
        "the scheduler claims pending prompts via atomic compare and swap",
        "tokens stream back to clients over server sent events",
        "expert routing is data dependent but not shape dependent",
        "the ring buffer is the only shared data structure",
    ];
    let handles: Vec<_> =
        prompts.iter().map(|p| server.submit_text(p, 16).expect("submit")).collect();
    for (p, h) in prompts.iter().zip(handles) {
        let toks = h.collect().map_err(|e| anyhow::anyhow!(e))?;
        let text = blink::tokenizer::decode(&server.frontend.vocab, &toks);
        println!("[moe] {:>2} tokens for {:?}\n      -> {:?}", toks.len(), p, text);
    }
    println!("[moe] scheduler: {}", server.scheduler.stats.summary());
    println!("[moe] no host round-trip occurred for any routing decision:");
    println!("      gating top-k executes inside each decode graph (L1 Pallas kernel).");
    server.shutdown();
    Ok(())
}

/// Artifacts-free path: same scheduler, same ring protocol, modeled
/// launches. Decode steps carry the expected-expert-union dispatch cost,
/// so the MoE tax is visible in the iteration stats.
fn run_modeled() -> anyhow::Result<()> {
    let manifest = modeled_moe_manifest();
    println!(
        "[moe] model={} experts={} top_k={} layers={} (moe={})",
        manifest.model, manifest.n_experts, manifest.top_k, manifest.n_layers, manifest.moe
    );
    let n = 4usize;
    println!(
        "[moe] expected expert union at batch {n}: {:.2} of {} experts",
        expected_active_experts(manifest.n_experts, manifest.top_k, n),
        manifest.n_experts,
    );

    let cost = ModeledCost {
        prefill_us_per_token: 20.0,
        decode_step_us: 300.0,
        expert_dispatch_us: 50.0,
    };
    let executor = Executor::spawn_modeled(&manifest, cost);
    let ring = Arc::new(RingBuffer::new(RingConfig {
        num_slots: 16,
        max_prompt: 64,
        max_output: 32,
    }));
    let mut sched = Scheduler::spawn(
        ring.clone(),
        executor,
        manifest,
        SchedulerConfig { placement: Placement::GpuResident, ..Default::default() },
    );

    let mut rng = Rng::new(9);
    for i in 0..n {
        let prompt: Vec<u32> = (0..24).map(|_| rng.below(2048) as u32).collect();
        assert!(ring.claim_for_write(i));
        ring.write_prompt(i, &prompt);
        ring.submit(i, i as u64, 24, 16, i as u32);
    }
    loop {
        let done = (0..n)
            .all(|i| matches!(ring.slot(i).state(), SlotState::DecodeCompleted | SlotState::Failed));
        if done {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    for i in 0..n {
        let generated = ring.slot(i).generated.load(std::sync::atomic::Ordering::Relaxed);
        println!("[moe] slot {i}: {generated} output tokens");
    }
    sched.drain_and_stop();
    println!("[moe] scheduler: {}", sched.stats.summary());
    println!("[moe] routing stays on-device either way: the host never sees an expert id.");
    Ok(())
}
