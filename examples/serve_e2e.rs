//! End-to-end serving driver (the validation run recorded in
//! EXPERIMENTS.md): start the full Blink stack on the tiny real model,
//! drive it with a Poisson workload through the DPU plane, and report
//! latency/throughput — the live analogue of the paper's guidellm runs.
//!
//!     cargo run --release --example serve_e2e -- [--rate 4] [--seconds 30]

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use blink::server::{BlinkServer, ServerConfig};
use blink::util::cli::Args;
use blink::util::rng::Rng;
use blink::util::stats::LatencySummary;
use blink::workload::LengthModel;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let rate = args.get_f64("rate", 4.0);
    let seconds = args.get_f64("seconds", 30.0);

    eprintln!("[e2e] starting Blink stack (AOT compile ~30s)...");
    let server = Arc::new(BlinkServer::start(ServerConfig::default())?);
    let http = blink::http::HttpServer::serve(
        "127.0.0.1:0",
        server.frontend.clone(),
        server.scheduler.stats.clone(),
    )?;
    eprintln!("[e2e] http on {}, offered load {rate} req/s for {seconds}s", http.addr);

    let lengths = LengthModel::sharegpt_tiny();
    let mut rng = Rng::new(0xE2E);
    // (ttft_ms, total_ms, tpot_ms, tokens)
    let results: Arc<Mutex<Vec<(f64, f64, f64, usize)>>> = Arc::new(Mutex::new(vec![]));
    let mut handles = vec![];
    let t0 = Instant::now();
    let mut submitted = 0usize;
    let mut next_arrival = 0.0f64;

    while t0.elapsed().as_secs_f64() < seconds {
        let now = t0.elapsed().as_secs_f64();
        if next_arrival > now {
            std::thread::sleep(Duration::from_secs_f64((next_arrival - now).min(0.1)));
            continue;
        }
        next_arrival += rng.exp(rate);
        let (in_len, out_len) = lengths.sample(&mut rng, 200, 48);
        let prompt: Vec<u32> = (0..in_len).map(|_| rng.below(2048) as u32).collect();
        let server = server.clone();
        let results = results.clone();
        submitted += 1;
        handles.push(std::thread::spawn(move || {
            let t_submit = Instant::now();
            let Ok(h) = server.submit_tokens(&prompt, out_len as u32) else { return };
            use blink::frontend::tracker::TokenEvent;
            let mut first: Option<Duration> = None;
            let mut count = 0usize;
            loop {
                match h.rx.recv() {
                    Ok(TokenEvent::Token(_)) => {
                        count += 1;
                        if first.is_none() {
                            first = Some(t_submit.elapsed());
                        }
                    }
                    Ok(TokenEvent::Done) | Ok(TokenEvent::Failed) | Err(_) => break,
                }
            }
            if let Some(f) = first {
                let total = t_submit.elapsed();
                let tpot = if count > 1 {
                    (total - f).as_secs_f64() * 1e3 / (count - 1) as f64
                } else {
                    0.0
                };
                results.lock().unwrap().push((
                    f.as_secs_f64() * 1e3,
                    total.as_secs_f64() * 1e3,
                    tpot,
                    count,
                ));
            }
        }));
    }
    for h in handles {
        let _ = h.join();
    }
    let wall = t0.elapsed().as_secs_f64();

    let res = results.lock().unwrap();
    let ttft: Vec<f64> = res.iter().map(|r| r.0).collect();
    let tpot: Vec<f64> = res.iter().filter(|r| r.2 > 0.0).map(|r| r.2).collect();
    let tokens: usize = res.iter().map(|r| r.3).sum();
    let ts = LatencySummary::from_samples(&ttft);
    let ps = LatencySummary::from_samples(&tpot);
    println!("\n== serve_e2e report (live blink-tiny, CPU PJRT) ==");
    println!("offered rate        {rate:.2} req/s for {seconds:.0}s");
    println!("submitted/completed {submitted}/{}", res.len());
    println!("req throughput      {:.2} req/s", res.len() as f64 / wall);
    println!("decode throughput   {:.1} tok/s", tokens as f64 / wall);
    println!("TTFT ms             mean {:.1}  p50 {:.1}  p99 {:.1}", ts.mean, ts.p50, ts.p99);
    println!("TPOT ms             mean {:.1}  p50 {:.1}  p99 {:.1}", ps.mean, ps.p50, ps.p99);
    println!("scheduler           {}", server.scheduler.stats.summary());
    let (ops, bytes) = server.rdma.stats();
    println!("rdma                {ops} verbs, {:.1} MB", bytes as f64 / 1e6);
    drop(http);
    if let Ok(s) = Arc::try_unwrap(server) {
        s.shutdown();
    }
    Ok(())
}
