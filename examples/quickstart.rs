//! Quickstart: start a Blink instance on the tiny model, submit one
//! prompt through the DPU plane, and stream the generated text.
//!
//!     make artifacts && cargo run --release --example quickstart

use blink::frontend::tracker::TokenEvent;
use blink::server::{BlinkServer, ServerConfig};
use blink::tokenizer::Detokenizer;

fn main() -> anyhow::Result<()> {
    println!("[quickstart] starting Blink (compiles AOT graphs once, ~30 s)...");
    let server = BlinkServer::start(ServerConfig::default())?;
    println!(
        "[quickstart] model={} layers={} vocab={} graphs={}",
        server.manifest.model,
        server.manifest.n_layers,
        server.manifest.vocab_size,
        server.manifest.graphs.len()
    );

    let prompt = "the quick brown fox jumps over the lazy dog and the persistent \
                  scheduler scans the ring buffer for newly submitted prompts";
    println!("[quickstart] prompt: {prompt:?}");
    let handle = server.submit_text(prompt, 32).map_err(|e| anyhow::anyhow!(e))?;
    println!(
        "[quickstart] submitted as request {} in ring slot {} ({} prompt tokens)",
        handle.request_id, handle.slot, handle.prompt_tokens
    );

    // Stream tokens as the DPU token reader delivers them.
    let mut detok = Detokenizer::new();
    let mut n = 0;
    print!("[quickstart] output: ");
    loop {
        match handle.rx.recv() {
            Ok(TokenEvent::Token(t)) => {
                n += 1;
                print!("{}", detok.push(&server.frontend.vocab, t));
                use std::io::Write;
                std::io::stdout().flush().ok();
            }
            Ok(TokenEvent::Done) => {
                println!("{}", detok.finish());
                break;
            }
            Ok(TokenEvent::Failed) => anyhow::bail!("generation failed"),
            Err(_) => anyhow::bail!("frontend dropped"),
        }
    }
    println!("[quickstart] generated {n} tokens");
    println!("[quickstart] scheduler: {}", server.scheduler.stats.summary());
    let (ops, bytes) = server.rdma.stats();
    println!("[quickstart] rdma: {ops} verbs, {bytes} bytes moved");
    server.shutdown();
    Ok(())
}
