//! Live colocation demo (the paper's Fig 1 / §6.3 story on real
//! hardware — this machine): run the same workload through the
//! GPU-resident scheduler and the CPU-resident baseline scheduler, first
//! isolated, then colocated with real memory-thrashing interferer
//! threads. The CPU-resident baseline degrades (its per-step host
//! orchestration contends for LLC); Blink's device-plane loop does not.
//!
//! Runs against compiled `blink-tiny` artifacts when they exist, and
//! falls back to the *modeled* executor otherwise — the scheduler
//! pipeline, ring protocol, and host-plane orchestration are identical,
//! so the interference comparison still measures the real control loop.
//!
//!     cargo run --release --example colocation -- [--requests 12] [--smoke]
//!
//! `--smoke` shrinks the workload and the antagonist for CI: few
//! requests, short outputs, two interferer threads.

use std::sync::Arc;
use std::time::{Duration, Instant};

use blink::eval::live::modeled_manifest;
use blink::gpu::{Executor, ModeledCost, Placement, PrefixReuse, Scheduler, SchedulerConfig};
use blink::hostsim::Interferer;
use blink::ringbuf::{RingBuffer, RingConfig, SlotState};
use blink::runtime::{artifacts_dir, ModelManifest};
use blink::util::cli::Args;
use blink::util::rng::Rng;

/// True when compiled blink-tiny artifacts are present (cheap check, no
/// executor spawn).
fn have_artifacts() -> bool {
    ModelManifest::load(&artifacts_dir().join("blink-tiny/manifest.txt")).is_ok()
}

/// Compiled artifacts when available, modeled executor otherwise. The
/// modeled decode cost is sized so host orchestration is a visible
/// fraction of each step — the same proportion the real engine shows.
fn spawn_engine() -> (ModelManifest, Executor) {
    let dir = artifacts_dir();
    if let Ok(manifest) = ModelManifest::load(&dir.join("blink-tiny/manifest.txt")) {
        if let Ok(executor) = Executor::spawn(dir, "blink-tiny".into()) {
            return (manifest, executor);
        }
    }
    let manifest = modeled_manifest();
    let cost = ModeledCost {
        prefill_us_per_token: 20.0,
        decode_step_us: 500.0,
        expert_dispatch_us: 0.0,
    };
    let executor = Executor::spawn_modeled(&manifest, cost);
    (manifest, executor)
}

struct RunResult {
    makespan_s: f64,
    iter_p50_us: f64,
    iter_p99_us: f64,
}

fn run_once(
    placement: Placement,
    n: usize,
    output: usize,
    interfere: bool,
    smoke: bool,
) -> RunResult {
    let (manifest, executor) = spawn_engine();
    let ring = Arc::new(RingBuffer::new(RingConfig {
        num_slots: 64,
        max_prompt: 128,
        max_output: 64,
    }));
    let mut sched = Scheduler::spawn(
        ring.clone(),
        executor,
        manifest,
        // prefix_reuse off: this example reproduces the paper's
        // interference comparison, which runs without prefix caching.
        SchedulerConfig {
            placement,
            apply_launch_delays: true,
            prefix_reuse: PrefixReuse::Off,
            ..Default::default()
        },
    );

    let interferer = if interfere {
        let threads = if smoke {
            2
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8)
        };
        Some(Interferer::spawn(threads, if smoke { 2 } else { 8 }))
    } else {
        None
    };
    std::thread::sleep(Duration::from_millis(if smoke { 50 } else { 200 })); // let interferers warm

    let mut rng = Rng::new(1);
    let t0 = Instant::now();
    for i in 0..n {
        let prompt: Vec<u32> = (0..48).map(|_| rng.below(2048) as u32).collect();
        assert!(ring.claim_for_write(i));
        ring.write_prompt(i, &prompt);
        ring.submit(i, i as u64, 48, output as u32, i as u32);
    }
    loop {
        let done = (0..n)
            .all(|i| matches!(ring.slot(i).state(), SlotState::DecodeCompleted | SlotState::Failed));
        if done {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    let makespan = t0.elapsed().as_secs_f64();
    if let Some(i) = interferer {
        i.stop();
    }
    sched.drain_and_stop();
    RunResult {
        makespan_s: makespan,
        iter_p50_us: sched.stats.iter_full_p50_us(),
        iter_p99_us: sched.stats.iter_full_p99_us(),
    }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let smoke = args.has_flag("smoke");
    let n = args.get_usize("requests", if smoke { 4 } else { 12 });
    let output = if smoke { 8 } else { 24 };
    let engine = if have_artifacts() {
        "compiled blink-tiny artifacts"
    } else {
        "modeled executor (no artifacts found)"
    };
    println!("[colocation] {n} requests x 48 prompt -> {output} output tokens ({engine})");
    if smoke {
        println!("[colocation] --smoke: CI sizing (2 interferer threads, short outputs)\n");
    } else {
        println!("[colocation] each cell loads the engine before measuring\n");
    }

    let configs: [(&str, Placement); 2] = [
        ("BLINK (GPU-resident)", Placement::GpuResident),
        (
            "baseline (CPU-resident)",
            Placement::CpuResident { scratch_mb: 16, touches_per_step: 400_000 },
        ),
    ];
    println!(
        "{:<26} {:>12} {:>12} {:>18} {:>22}",
        "scheduler", "isolated(s)", "colocated(s)", "colocated/isolated", "co iter p50/p99 (µs)"
    );
    for (name, placement) in configs {
        let iso = run_once(placement.clone(), n, output, false, smoke);
        let co = run_once(placement.clone(), n, output, true, smoke);
        println!(
            "{:<26} {:>12.2} {:>12.2} {:>18.2} {:>14.1}/{:>6.1}",
            name,
            iso.makespan_s,
            co.makespan_s,
            co.makespan_s / iso.makespan_s,
            co.iter_p50_us,
            co.iter_p99_us,
        );
    }
    println!("\n(paper Fig 1: baselines retain 28-54 % of isolated throughput; BLINK ~100 %)");
    println!("(deterministic-antagonist version: `blink eval interference`)");
}
