//! Live colocation demo (the paper's Fig 1 / §6.3 story on real
//! hardware — this machine): run the same workload through the
//! GPU-resident scheduler and the CPU-resident baseline scheduler, first
//! isolated, then colocated with real memory-thrashing interferer
//! threads. The CPU-resident baseline degrades (its per-step host
//! orchestration contends for LLC); Blink's device-plane loop does not.
//!
//!     cargo run --release --example colocation -- [--requests 12]

use std::sync::Arc;
use std::time::{Duration, Instant};

use blink::gpu::{Executor, Placement, PrefixReuse, Scheduler, SchedulerConfig};
use blink::hostsim::Interferer;
use blink::ringbuf::{RingBuffer, RingConfig, SlotState};
use blink::runtime::{artifacts_dir, ModelManifest};
use blink::util::cli::Args;
use blink::util::rng::Rng;

fn run_once(placement: Placement, n: usize, interfere: bool) -> f64 {
    let dir = artifacts_dir();
    let manifest = ModelManifest::load(&dir.join("blink-tiny/manifest.txt")).expect("manifest");
    let ring = Arc::new(RingBuffer::new(RingConfig {
        num_slots: 64,
        max_prompt: 128,
        max_output: 64,
    }));
    let executor = Executor::spawn(dir, "blink-tiny".into()).expect("executor");
    let mut sched = Scheduler::spawn(
        ring.clone(),
        executor,
        manifest,
        // prefix_reuse off: this example reproduces the paper's
        // interference comparison, which runs without prefix caching.
        SchedulerConfig {
            placement,
            apply_launch_delays: true,
            prefix_reuse: PrefixReuse::Off,
            ..Default::default()
        },
    );

    let interferer = if interfere {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);
        Some(Interferer::spawn(threads, 8))
    } else {
        None
    };
    std::thread::sleep(Duration::from_millis(200)); // let interferers warm

    let mut rng = Rng::new(1);
    let t0 = Instant::now();
    for i in 0..n {
        let prompt: Vec<u32> = (0..48).map(|_| rng.below(2048) as u32).collect();
        assert!(ring.claim_for_write(i));
        ring.write_prompt(i, &prompt);
        ring.submit(i, i as u64, 48, 24, i as u32);
    }
    loop {
        let done = (0..n)
            .all(|i| matches!(ring.slot(i).state(), SlotState::DecodeCompleted | SlotState::Failed));
        if done {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    let makespan = t0.elapsed().as_secs_f64();
    if let Some(i) = interferer {
        i.stop();
    }
    sched.drain_and_stop();
    makespan
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.get_usize("requests", 12);
    println!("[colocation] {n} requests x 48 prompt -> 24 output tokens, blink-tiny (live)");
    println!("[colocation] each cell loads+compiles the engine (~30s) before measuring\n");

    let configs: [(&str, Placement); 2] = [
        ("BLINK (GPU-resident)", Placement::GpuResident),
        (
            "baseline (CPU-resident)",
            Placement::CpuResident { scratch_mb: 16, touches_per_step: 400_000 },
        ),
    ];
    println!(
        "{:<26} {:>12} {:>12} {:>18}",
        "scheduler", "isolated(s)", "colocated(s)", "colocated/isolated"
    );
    for (name, placement) in configs {
        let iso = run_once(placement.clone(), n, false);
        let co = run_once(placement.clone(), n, true);
        println!("{:<26} {:>12.2} {:>12.2} {:>18.2}", name, iso, co, co / iso);
    }
    println!("\n(paper Fig 1: baselines retain 28-54 % of isolated throughput; BLINK ~100 %)");
}
